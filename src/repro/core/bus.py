"""MessageBus — the NATS analog (paper §4, "Message bus").

Subject-based pub/sub with:

* **registration + authorization** — "only services deployed on DataX will be
  able to connect ... they will be able to subscribe and publish only on the
  defined and registered streams."  Publishing to an unregistered subject, or
  with a token that is not authorized for that subject, raises.
* **bounded subscriber queues** with a drop-oldest policy (streams are lossy
  real-time flows; the sidecar counts drops and reports them as metrics).
* **delivery policies** — how a subject's subscribers share its messages is a
  first-class, pluggable layer (the DataX claim that the *platform* picks the
  right communication mechanism):

  - ``broadcast`` — every ungrouped subscription receives every message
    (§3 stream reuse; the default for plain ``subscribe``).
  - ``group`` (:class:`QueueGroup`, the NATS queue-group analog) —
    ``subscribe(..., group="owner")`` joins a named single-delivery group:
    each message is round-robined to exactly ONE healthy member per group,
    while still fanning out to every ungrouped subscription and every
    *other* group.  Scaled instances of one stream join one group (a worker
    pool, N instances = N× capacity).
  - ``keyed`` (:class:`KeyedGroup`) — ``subscribe(..., group=..., key=...)``
    hashes the declared payload field onto a stable partition ring
    (rendezvous hashing over :data:`KEYED_PARTITIONS` partitions): every
    message for a key lands on the SAME healthy member, which is what makes
    *stateful* scaled streams safe (per-key state + per-key order).  A
    departing member's partitions move — whole and in order — to survivors;
    no other partition moves (minimal disruption, property-tested).

* **schema enforcement** — each subject carries a StreamSchema; publishes are
  validated against it (homogeneous streams, §2).
* **wire serialization** — msgpack (+numpy) encode/decode used when a message
  crosses a host boundary.  In-process delivery passes payloads by reference;
  ``wire=True`` subscriptions force the encode/decode round-trip, which tests
  use to prove payloads are wire-safe.
* **durable subjects** (``durable.py``) — :meth:`MessageBus.make_durable`
  attaches an append-only :class:`~.durable.DurableLog` to a subject;
  ``publish`` then appends BEFORE delivering and stamps the log position on
  the message as ``headers["offset"]``.  ``subscribe(replay_from=...)``
  drains that history (offset / timestamp / ``"earliest"``) and hands off to
  live delivery with **no gaps and no duplicates**: append-before-deliver
  means every offset below the head is readable from the log, the
  replay→live flip happens only when the cursor has reached the head (under
  the group lock, so no publish can slip between the check and the flip),
  and live items whose offset falls inside the replayed range are deduped.
  A replaying member of a round-robin group is NOT counted healthy for live
  delivery until caught up — otherwise live messages would interleave ahead
  of history in its mailbox (its share is healed from the log, not lost).
  Keyed members keep their ring partitions while replaying: live messages
  queue behind the replay and the cursor dedupe drops the overlap at the
  flip, which also keeps partitions from moving twice per recovery.

:class:`MessageBus` is the in-process implementation of the platform's
**transport seam** (:class:`BusLike`): everything instance-facing — the
sidecar's subscriptions and publishes, the executor's worker pools — is
written against that surface, so a process can swap in
:class:`~.transport.RemoteBus` (a TCP client speaking length-prefixed
codec-tagged frames to a :class:`~.transport.BusServer` wrapping a bus like
this one) and join queue groups and keyed rings across host boundaries
without any other code changing.  See ``docs/wire-protocol.md``.
"""
from __future__ import annotations

import hashlib
import io
import queue
import threading
import time
from collections import deque
from typing import TYPE_CHECKING, Iterable, Protocol, Sequence

import msgpack
import numpy as np

from .delivery import (KEYED_PARTITIONS, DeliveryPolicy, ReplayFrom,
                       resolve_policy, resolve_replay)
from .schema import Message, StreamSchema

if TYPE_CHECKING:  # pragma: no cover - durable imports encode_message from us
    from .durable import DurableLog, Retention


# ---------------------------------------------------------------------------
# Wire format: msgpack with an extension for numpy arrays
# ---------------------------------------------------------------------------

_NDARRAY_EXT = 42


def _default(obj):
    if isinstance(obj, np.ndarray):
        buf = io.BytesIO()
        np.save(buf, obj, allow_pickle=False)
        return msgpack.ExtType(_NDARRAY_EXT, buf.getvalue())
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot serialize {type(obj).__name__} on the wire")


def _ext_hook(code, data):
    if code == _NDARRAY_EXT:
        return np.load(io.BytesIO(data), allow_pickle=False)
    return msgpack.ExtType(code, data)


def encode_payload(payload: dict) -> bytes:
    """Wire-encode one payload dict: numpy-aware msgpack (ndarrays travel
    as ExtType 42 ``.npy`` bytes, ``allow_pickle=False``)."""
    return msgpack.packb(payload, default=_default, use_bin_type=True)


def decode_payload(raw: bytes) -> dict:
    """Inverse of :func:`encode_payload`."""
    return msgpack.unpackb(raw, ext_hook=_ext_hook, raw=False, strict_map_key=False)


def encode_message(msg: Message) -> bytes:
    """Wire-encode a full :class:`Message` envelope (subject, seq, ts,
    headers, payload) — the byte format shared by ``wire=True``
    subscriptions, durable-log records, and transport ``msg`` frames."""
    return msgpack.packb(
        {"subject": msg.subject, "seq": msg.seq, "ts": msg.ts,
         "headers": msg.headers, "payload": msg.payload},
        default=_default, use_bin_type=True)


def decode_message(raw: bytes) -> Message:
    """Inverse of :func:`encode_message`."""
    d = msgpack.unpackb(raw, ext_hook=_ext_hook, raw=False, strict_map_key=False)
    return Message(subject=d["subject"], payload=d["payload"], seq=d["seq"],
                   ts=d["ts"], headers=d.get("headers", {}))


# ---------------------------------------------------------------------------
# Errors
# ---------------------------------------------------------------------------

class BusError(RuntimeError):
    pass


class Unauthorized(BusError):
    pass


class UnknownSubject(BusError):
    pass


#: A member shallower than this is never a steal victim: moving one queued
#: message buys nothing over letting the victim finish it, and the group-lock
#: round-trip would dominate.  Two is the floor at which splitting helps.
STEAL_MIN_BACKLOG = 2


# ---------------------------------------------------------------------------
# The transport seam
# ---------------------------------------------------------------------------

class BusLike(Protocol):
    """The transport seam: what an instance-facing bus must provide.

    :class:`MessageBus` (in-process delivery) and
    :class:`~.transport.RemoteBus` (a TCP client whose subscriptions are
    first-class queue-group / keyed-ring members on a remote host's bus)
    both satisfy this surface, and :class:`~.sidecar.Sidecar` /
    :class:`~.serverless.Executor` are written against it alone — which is
    what makes the platform's data plane transport-pluggable (the DataX
    claim that the *platform* owns the communication mechanism).
    """

    def subscribe(self, subject: str, *, token: str,
                  maxsize: int | None = None, wire: bool = False,
                  name: str = "", policy: DeliveryPolicy | None = None,
                  replay: ReplayFrom | None = None,
                  group: str | None = None, key: str | None = None,
                  partitions: int | None = None, replay_from=None):
        """Open a subscription; kwargs match :meth:`MessageBus.subscribe`
        (``policy``/``replay`` are the typed forms; the bare kwargs are the
        deprecated spelling)."""
        ...

    def unsubscribe(self, sub) -> None:
        """Leave the subject (group members re-home their backlog)."""
        ...

    def publish(self, subject: str, payload: dict, *, token: str,
                headers: dict | None = None):
        """Publish one payload; raises on authz/schema/subject errors."""
        ...

    def issue_token(self, name: str,
                    subjects: Iterable[str] | None = None) -> str:
        """Mint an auth token scoped to ``subjects`` (None = all)."""
        ...

    def revoke_token(self, token: str) -> None:
        """Invalidate a token."""
        ...

    def note_lost(self, subject: str, n: int = 1) -> None:
        """Account messages destroyed after delivery (poison messages)."""
        ...

    def group_info(self, subject: str, group: str) -> dict | None:
        """Snapshot of one queue group (None if it does not exist)."""
        ...

    def durable_log(self, subject: str):
        """The subject's durable log (or a remote handle to it), or None."""
        ...


# ---------------------------------------------------------------------------
# The partition ring (pure functions — property-tested)
# ---------------------------------------------------------------------------

# KEYED_PARTITIONS (the default ring size) now lives in delivery.py next to
# the Keyed policy that carries it; imported above and re-exported here for
# the long-standing `from repro.core.bus import KEYED_PARTITIONS` spelling.


def stable_hash(value) -> int:
    """Deterministic, process-independent 64-bit hash over canonical bytes.

    blake2s, not crc32/python-hash: python's hash is salted per process (the
    ring must agree across restarts and, eventually, hosts), and crc32 is
    *affine* — member names that differ only in an instance counter digit
    would get rendezvous weights whose relative order repeats across
    partitions, piling half the ring onto one member.  A cryptographic hash
    makes every (partition, member) weight independent.
    """
    if isinstance(value, bytes):
        data = value
    elif isinstance(value, str):
        data = value.encode("utf-8")
    else:
        data = repr(value).encode("utf-8")
    return int.from_bytes(hashlib.blake2s(data, digest_size=8).digest(), "big")


def partition_of(key, n_partitions: int = KEYED_PARTITIONS) -> int:
    """Key value -> partition index.  Same key, same partition — forever."""
    return stable_hash(key) % n_partitions


def partition_owner(partition: int, members: Sequence[str]) -> str | None:
    """Rendezvous (highest-random-weight) owner of ``partition``.

    Stability + minimal disruption come from scoring every (partition,
    member) pair independently: while membership is unchanged the argmax is
    constant; removing a member only re-homes the partitions it was winning
    (each to its runner-up); adding one only claims the partitions it now
    wins.  No other partition moves."""
    best, best_w = None, -1
    for m in members:
        w = stable_hash(f"{partition}|{m}")
        if w > best_w or (w == best_w and (best is None or m < best)):
            best, best_w = m, w
    return best


def ring_assignment(members: Sequence[str],
                    n_partitions: int = KEYED_PARTITIONS) -> dict[int, str]:
    """The full partition->member map for a membership set."""
    return {p: partition_owner(p, members) for p in range(n_partitions)}


# ---------------------------------------------------------------------------
# Subscriptions
# ---------------------------------------------------------------------------

class Subscription:
    """A bounded mailbox bound to one subject.

    ``group`` is the queue-group name this subscription joined (None =
    ungrouped broadcast subscriber).  Drops are counted per subscription and
    surfaced through ``MessageBus.stats()`` — a nonzero count means this
    consumer is losing data and is a hard scale-up signal for the autoscaler.

    Mailbox items are stored as ``(tag, item)`` pairs; ``tag`` is the keyed
    partition index (None for broadcast/round-robin delivery), which is how
    the bus keeps an exact per-partition backlog without touching payloads.

    On a **durable** subject the subscription also carries replay state: a
    cursor (the next log offset it expects), set either at the live head
    (plain subscribe — used to heal drop-oldest gaps from the log on
    broadcast subscriptions) or at a historical position
    (``replay_from=...`` — :meth:`next_batch` then serves from the log until
    the cursor reaches the head, flips to live atomically against the
    group's pick, and dedupes the overlap).
    """

    def __init__(self, subject: str, maxsize: int, wire: bool, name: str = "",
                 group: str | None = None):
        self.subject = subject
        self.name = name or f"sub-{id(self):x}"
        self.wire = wire
        self.group = group
        self._q: queue.Queue = queue.Queue(maxsize=maxsize)
        self.received = 0
        self.dropped = 0
        self.closed = False
        self._lock = threading.Lock()
        # set by KeyedGroup.add: consumption callback for partition backlog
        self._keyed_group: "KeyedGroup | None" = None
        # durable replay state (set by MessageBus.subscribe on durable
        # subjects; _group_ref is the QueueGroup whose lock orders the
        # replay->live flip against concurrent pick()s)
        self._log: "DurableLog | None" = None
        self._group_ref: "QueueGroup | None" = None
        self._replay_start = 0      # first offset this sub replayed itself
        self._replayed_upto = 0     # frozen at flip: log served [start, upto)
        self._cursor = 0            # next offset it expects to see
        self._join_head = 0         # log head when this sub joined (offsets
        #                             at/after it were published live to it)
        self._replayed_set: set = set()  # post-join offsets actually served
        #                             from the log (keyed replay filters, so
        #                             a range cannot stand in for this set)
        self._replay_active = False
        self.replayed = 0           # messages served from the log
        self.deduped = 0            # live messages dropped as replay overlap
        self.healed = 0             # drop-oldest gaps refilled from the log
        # gap-heal surplus: healing inside next_batch can surface MORE than
        # max_n messages; the overflow queues here and is served first on
        # the next pop (single-consumer, like the mailbox itself)
        self._pending: deque = deque()
        # work stealing: the partition tags of the burst this consumer popped
        # and has not finished (replaced atomically with the pop, under the
        # mailbox's queue mutex) — a thief must never take a partition the
        # victim is still processing, or the key's order would fork.  For
        # transport proxy subscriptions, _external_inflight is a callable
        # returning the tags shipped over the wire but not yet acked.
        self._inflight_tags: set = set()
        self._external_inflight = None

    @property
    def replaying(self) -> bool:
        """True until the replay cursor has caught the log head.  A replaying
        member is skipped by round-robin live delivery (its share is healed
        from the log) — the guard that keeps live messages from interleaving
        ahead of history."""
        return self._replay_active

    def replay_lag(self) -> int:
        """Offsets between this subscription's cursor and the log head
        (0 = caught up / not durable) — the sidecar's replay-lag metric."""
        if self._log is None:
            return 0
        return max(0, self._log.next_offset() - self._cursor)

    def _note_consumed(self, tag) -> None:
        if tag is not None and self._keyed_group is not None:
            self._keyed_group.note_consumed(tag)

    def _offer(self, item, tag=None) -> bool:
        """Enqueue with drop-oldest on overflow (lossy stream semantics).

        Returns False when the mailbox is closed (counted as a drop here so
        the refusal is never silent; a group-delivery caller re-picks another
        member so the message still reaches a survivor)."""
        with self._lock:
            if self.closed:
                self.dropped += 1
                return False
            while True:
                try:
                    self._q.put_nowait((tag, item))
                    self.received += 1
                    return True
                except queue.Full:
                    try:
                        old = self._q.get_nowait()
                        self.dropped += 1
                        if old is not None:
                            self._note_consumed(old[0])
                    except queue.Empty:  # pragma: no cover - race guard
                        pass

    def next(self, timeout: float | None = None) -> Message | None:
        """Blocking pop; None on timeout or close."""
        got = self.next_batch(1, timeout)
        return got[0] if got else None

    def next_batch(self, max_n: int,
                   timeout: float | None = None) -> list[Message]:
        """Pop up to ``max_n`` queued items as one burst, preserving order.

        Blocks up to ``timeout`` for the FIRST item only — a shallow mailbox
        costs exactly one :meth:`next`, so batching consumers keep unbatched
        idle latency — then drains whatever else is already queued under ONE
        mailbox-lock acquisition, without waiting for more to arrive.
        Group/keyed ``note_consumed`` accounting (per-partition backlog) and
        wire decoding match :meth:`next` item for item.  Returns ``[]`` on
        timeout or close.

        On a durable subject this is also where replay and the gapless
        handoff live: while replaying, batches come from the log; once the
        cursor reaches the head the subscription flips to the mailbox, where
        offsets inside the replayed range are deduped and (broadcast only)
        offsets beyond the cursor trigger a log refill of whatever
        drop-oldest evicted.  May return ``[]`` before the timeout when a
        whole batch deduped away — callers already treat ``[]`` as a tick.
        """
        if max_n < 1:
            return []
        if self._replay_active:
            got = self._replay_batch(max_n)
            if got:
                return got
            # caught up — flipped to live; fall through to the mailbox
        if self._pending:
            # surplus from an earlier gap-heal — serve it before touching
            # the mailbox so healed offsets keep their order
            out = []
            while self._pending and len(out) < max_n:
                out.append(self._pending.popleft())
            return out
        grp = self._group_ref
        if (grp is not None and grp.steal_enabled and not self.closed
                and self._q.qsize() == 0):
            # idle member of a steal-enabled group: pull queued work from the
            # deepest healthy peer BEFORE blocking on the empty mailbox —
            # pull-based work stealing (a straggler's share stops waiting
            # behind it).  Partition-granular for keyed groups.
            grp.steal_into(self)
        q = self._q
        pairs: list = []
        # One acquisition for the wait AND the whole drain (vs max_n
        # get_nowait round-trips).  Safe to touch the internals: producers
        # only ever put_nowait (nobody waits on not_full), and removing items
        # never requires a not_empty notification.  The inflight-tag set is
        # replaced under the same mutex as the pop, so a steal (which reads
        # it under this mutex) can never observe a popped item without its
        # tag marked busy.
        with q.not_empty:
            if not q._qsize():
                if timeout is None:
                    while not q._qsize():
                        q.not_empty.wait()
                else:
                    deadline = time.monotonic() + timeout
                    while not q._qsize():
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            break
                        q.not_empty.wait(remaining)
            while len(pairs) < max_n and q._qsize():
                pairs.append(q._get())
            self._inflight_tags = {p[0] for p in pairs
                                   if p is not None and p[0] is not None}
        if not pairs:
            return []
        out: list[Message] = []
        for pair in pairs:
            if pair is None:
                break  # close sentinel — it is always the last item
            tag, item = pair
            self._note_consumed(tag)
            msg = decode_message(item) if self.wire else item
            if self._log is not None:
                off = msg.headers.get("offset")
                if off is not None:
                    if (self._replay_start <= off < self._join_head
                            or off in self._replayed_set):
                        # replay overlap: this copy was served from the log
                        # — NOT a loss.  Pre-join history is a contiguous
                        # range; post-join offsets are tracked exactly,
                        # because keyed replay filters peer-owned offsets
                        # out of the log stream and their live copies (e.g.
                        # an adopted orphan partition) must pass through.
                        self.deduped += 1
                        continue
                    if off > self._cursor and self.group is None:
                        # broadcast mailbox overflowed (drop-oldest) — the
                        # durable log still has the evicted span; refill it
                        # so the consumer sees every offset exactly once.
                        # Group members skip this: their mailbox offsets are
                        # legitimately sparse (peers own the rest).
                        out.extend(self._heal_gap(off))
                    if off >= self._cursor:
                        self._cursor = off + 1
            out.append(msg)
        if len(out) > max_n:
            # gap-heal grew the batch past what the caller asked for —
            # park the tail; the next pop serves it before the mailbox
            self._pending.extend(out[max_n:])
            out = out[:max_n]
        return out

    def _replay_batch(self, max_n: int) -> list[Message]:
        """One replay step: a batch from the log, or ``[]`` after atomically
        flipping to live delivery because the cursor reached the head."""
        while True:
            msgs = self._log.read(self._cursor, max_n)  # type: ignore[union-attr]
            if msgs:
                self._cursor = msgs[-1].headers["offset"] + 1
                kg = self._keyed_group
                if kg is not None:
                    # A keyed member replays pre-join history in full, but an
                    # offset appended AFTER it joined the ring is already
                    # being delivered live to its partition's owner — serving
                    # a peer-owned copy from the log here would double-deliver
                    # it across the group.  Own partitions still come from the
                    # log (the live mailbox copy dedupes at the flip).  Steal
                    # overrides count: a stolen partition's live owner is the
                    # thief, not the ring.
                    ring = kg.effective_assignment()
                    msgs = [m for m in msgs
                            if m.headers["offset"] < self._join_head
                            or ring.get(partition_of(m.payload.get(kg.key),
                                                     kg.n_partitions))
                            == self.name]
                    if not msgs:
                        continue  # the whole span was peers' — keep reading
                for m in msgs:
                    off = m.headers["offset"]
                    if off >= self._join_head:
                        # published live while replaying — a mailbox copy may
                        # exist and must be deduped at the flip
                        self._replayed_set.add(off)
                self.replayed += len(msgs)
                return msgs
            if self.closed:
                self._replayed_upto = self._cursor
                self._replay_active = False
                return []
            # Nothing left to read — but a publish may append between that
            # read and here.  The flip must serialize against the group's
            # pick(): publish appends BEFORE picking, so under the group
            # lock "cursor >= head" proves every picked-while-replaying
            # message is already behind the cursor, and every later publish
            # will see this member live.  Ungrouped subs flip under their
            # mailbox lock (broadcast delivery needs no pick).
            lock = self._group_ref._lock if self._group_ref is not None \
                else self._lock
            with lock:
                if self._cursor >= self._log.next_offset():  # type: ignore[union-attr]
                    self._replayed_upto = self._cursor
                    self._replay_active = False
                    kg = self._keyed_group
                    if kg is not None and kg._orphaned and not any(
                            m.replaying for m in kg.members):
                        # recovery complete: every orphaned partition's
                        # history is replayed — the ring owns them again
                        kg._orphaned.clear()
                    return []
            # lost the race with a publish — loop; the next read finds it

    def _heal_gap(self, upto: int) -> list[Message]:
        """Refill ``[cursor, upto)`` from the log (drop-oldest healing).
        Offsets already evicted by retention stay lost (counted as drops
        when they were evicted)."""
        healed: list[Message] = []
        while self._cursor < upto:
            got = [m for m in
                   self._log.read(self._cursor, upto - self._cursor)  # type: ignore[union-attr]
                   if m.headers["offset"] < upto]
            if not got:
                break  # span evicted by retention
            healed.extend(got)
            self._cursor = got[-1].headers["offset"] + 1
        self.healed += len(healed)
        return healed

    def requeue_front(self, pairs: Sequence[tuple]) -> int:
        """Re-insert undelivered ``(tag, item)`` pairs at the FRONT of the
        mailbox, oldest first, restoring keyed per-partition backlog counts.

        The transport layer uses this when a remote peer drops: frames that
        were shipped over the wire but never acknowledged go back ahead of
        the still-queued backlog *before* the peer's proxy subscription
        departs, so the group's atomic hand-off re-homes them to survivors
        in their original order (per-key order holds across a peer crash
        exactly as it does across an in-process departure).  The mailbox may
        temporarily exceed ``maxsize`` — requeued items are never dropped.
        Returns the number requeued (0 when the mailbox is already closed;
        the caller's departure path then accounts them as lost)."""
        if not pairs:
            return 0
        with self._lock:
            if self.closed:
                self.dropped += len(pairs)
                return 0
            q = self._q
            with q.mutex:
                for tag, item in reversed(pairs):
                    q.queue.appendleft((tag, item))
                q.not_empty.notify(len(pairs))
            for tag, _ in pairs:
                if tag is not None and self._keyed_group is not None:
                    self._keyed_group.note_requeued(tag)
            return len(pairs)

    def qsize(self) -> int:
        return self._q.qsize() + len(self._pending)

    def _seal(self) -> None:
        """Mark closed WITHOUT waking readers (no sentinel, no eviction).

        Departing-group-member hand-off step 1: once sealed, every further
        ``_offer`` is refused and counted, so a publisher that picked this
        member just before it left the rotation cannot slip a message in
        after the backlog drain (offer and seal serialize on the mailbox
        lock).  ``close()`` afterwards still delivers the reader sentinel.
        """
        with self._lock:
            self.closed = True

    def _drain_pending(self) -> list:
        """Pop everything still queued as ``(tag, item)`` pairs (items
        possibly wire blobs).

        Used when a group member departs: under single delivery its queued
        messages are the only copies, so the bus hands them to the surviving
        members instead of garbage-collecting them.  Call after ``_seal()``.
        """
        items = []
        while True:
            try:
                pair = self._q.get_nowait()
            except queue.Empty:
                return items
            if pair is not None:
                items.append(pair)

    def close(self) -> None:
        with self._lock:
            self.closed = True
            # Wake blocked readers.  If the mailbox is full, evict one item so
            # the sentinel always lands — otherwise a reader blocked in next()
            # would never observe the close.
            while True:
                try:
                    self._q.put_nowait(None)
                    return
                except queue.Full:
                    try:
                        old = self._q.get_nowait()
                        self.dropped += 1
                        if old is not None:
                            self._note_consumed(old[0])
                    except queue.Empty:  # pragma: no cover - race guard
                        pass


class QueueGroup:
    """A named single-delivery group on one subject (NATS queue-group analog).

    The base class implements the ``group`` delivery policy: round-robin to
    the next *healthy* (non-closed) member, skipping dead ones so a member
    dying mid-rotation re-routes its share to the survivors.
    :class:`KeyedGroup` subclasses it for the ``keyed`` policy — the two
    differ only in how a message picks its member and how a departing
    member's backlog re-homes, which is exactly the pluggable surface
    (:meth:`_pick_locked` / :meth:`_repick_locked`).

    The round-robin cursor tracks the next member's *identity*, not an index,
    so a removal can never skew the rotation: removing any member other than
    the cursor leaves the cursor in place, and removing the cursor moves it
    to that member's successor — the survivor after a departure is never
    double-picked (regression-tested exhaustively).

    Membership changes happen under the bus lock; the group's own lock orders
    ``pick()`` against them (lock order is always bus → group, so no
    deadlock).  :meth:`depart` runs the seal-drain-reroute hand-off of a
    leaving member atomically under the group lock, so no concurrent publish
    can be delivered to the new owner ahead of the rerouted backlog — that
    atomicity is what upgrades "re-route" to *ordered* re-route for keyed
    groups.
    """

    policy = "group"

    def __init__(self, subject: str, name: str):
        self.subject = subject
        self.name = name
        self.members: list[Subscription] = []
        self._next: Subscription | None = None   # round-robin cursor (identity)
        self.delivered = 0            # hand-offs to a member (incl. re-routes)
        self.undeliverable = 0        # published while no healthy member
        self.rerouted = 0             # departing-member backlog re-deliveries
        self.steal_enabled = False    # set by subscribe(policy=...steal=True)
        self.stolen = 0               # messages pulled by an idle member
        self.steal_denied = 0         # steal attempts a deep victim refused
        self._lock = threading.Lock()

    # -- membership -----------------------------------------------------------
    def add(self, sub: Subscription) -> None:
        with self._lock:
            self._add_locked(sub)

    def _add_locked(self, sub: Subscription) -> None:
        self.members.append(sub)
        if self._next is None:
            self._next = sub

    def remove(self, sub: Subscription) -> bool:
        """Remove a member; True if the group is now empty."""
        with self._lock:
            self._remove_locked(sub)
            return not self.members

    def _remove_locked(self, sub: Subscription) -> None:
        if sub not in self.members:
            return
        if self._next is sub:
            # cursor moves to the departing member's successor, never back
            # to the member just picked (the old index arithmetic's risk)
            i = self.members.index(sub)
            self._next = self.members[(i + 1) % len(self.members)] \
                if len(self.members) > 1 else None
        self.members.remove(sub)

    def is_empty(self) -> bool:
        with self._lock:
            return not self.members

    # -- the delivery policy surface ------------------------------------------
    def _pick_locked(self, msg) -> tuple[Subscription | None, object]:
        """(member, tag) for a fresh message; None when no healthy member.

        Base policy: round-robin from the cursor, skipping closed members —
        and **replaying** ones: a member still draining durable history must
        not receive live messages, or they would interleave ahead of that
        history in its mailbox.  Its skipped share is not lost — the subject
        is durable (replay implies a log), so the member reads those offsets
        from the log before it flips live.
        """
        n = len(self.members)
        if n == 0:
            return None, None
        start = self.members.index(self._next) if self._next in self.members \
            else 0
        for i in range(n):
            m = self.members[(start + i) % n]
            if not m.closed and not m.replaying:
                self._next = self.members[(start + i + 1) % n]
                return m, None
        return None, None

    def _repick_locked(self, tag, item) -> tuple[Subscription | None, object]:
        """(member, tag) for a departing member's drained backlog item.

        Base policy: same round-robin as fresh messages."""
        return self._pick_locked(None)

    # -- data plane ------------------------------------------------------------
    def pick(self, msg: Message | None = None) -> tuple[Subscription | None, object]:
        """Pick the member for ``msg``; returns ``(member, tag)``.

        ``tag`` is policy-private routing state (the partition index for
        keyed groups) that the caller must hand to ``member._offer`` and to
        :meth:`unpick` on a refused offer."""
        with self._lock:
            member, tag = self._pick_locked(msg)
            if member is None:
                self.undeliverable += 1
            else:
                self.delivered += 1
            return member, tag

    def unpick(self, tag=None) -> None:
        """Roll back a pick() whose offer was refused (member sealed by a
        racing departure) so ``delivered`` stays exact before the re-pick."""
        with self._lock:
            self.delivered -= 1
            self._unpick_tag_locked(tag)

    def _unpick_tag_locked(self, tag) -> None:
        pass

    def note_consumed(self, tag) -> None:
        """A mailbox popped (or evicted) an item tagged ``tag``."""
        pass

    def note_requeued(self, tag) -> None:
        """A popped item tagged ``tag`` went back into a mailbox unconsumed
        (transport redelivery via :meth:`Subscription.requeue_front`)."""
        pass

    # -- pull-based work stealing ---------------------------------------------
    def steal_into(self, thief: Subscription) -> int:
        """Move queued work from the deepest healthy member to idle ``thief``.

        Called by an idle member's own consumer thread (from
        :meth:`Subscription.next_batch`, before it blocks on its empty
        mailbox).  The whole steal holds the group lock, so it serializes
        against pick()/depart() — a racing publish or departure sees either
        the pre- or post-steal queues, never a half-moved partition.  Returns
        the number of messages moved; a deep victim that refuses (every
        queued partition busy or orphaned) counts as ``steal_denied``, no
        victim deep enough counts as neither.
        """
        if thief.closed or thief.replaying:
            return 0
        with self._lock:
            if thief not in self.members:
                return 0
            moved, had_victim = self._steal_locked(thief)
            if moved:
                self.stolen += moved
            elif had_victim:
                self.steal_denied += 1
            return moved

    def _deepest_victim_locked(self,
                               thief: Subscription) -> Subscription | None:
        best, depth = None, STEAL_MIN_BACKLOG - 1
        for m in self.members:
            if m is thief or m.closed or m.replaying:
                continue
            d = m._q.qsize()
            if d > depth:
                best, depth = m, d
        return best

    def _steal_locked(self, thief: Subscription) -> tuple[int, bool]:
        """(messages moved, deep-victim existed).  Base policy: take half the
        victim's queued tail — round-robin delivery makes no ordering promise
        across members, so any split is safe."""
        victim = self._deepest_victim_locked(thief)
        if victim is None:
            return 0, False
        q = victim._q
        with q.mutex:
            take = q._qsize() // 2
            tail, sentinel = [], False
            for _ in range(take):
                pair = q.queue.pop()
                if pair is None:  # close sentinel — stays with the victim
                    sentinel = True
                    continue
                tail.append(pair)
            if sentinel:
                q.queue.append(None)
            tail.reverse()
        if not tail:
            return 0, True
        self._transfer_locked(victim, thief, tail)
        return len(tail), True

    def _transfer_locked(self, victim: Subscription, thief: Subscription,
                         pairs: list) -> None:
        """Append stolen ``(tag, item)`` pairs to the thief's mailbox tail,
        converting wire format when the two subscriptions disagree.  Like
        :meth:`Subscription.requeue_front`, the thief may temporarily exceed
        ``maxsize`` — stolen items are never dropped."""
        converted = []
        for tag, item in pairs:
            if victim.wire != thief.wire:
                item = encode_message(item) if thief.wire \
                    else decode_message(item)
            converted.append((tag, item))
        tq = thief._q
        with tq.mutex:
            tq.queue.extend(converted)
            tq.not_empty.notify(len(converted))

    def depart(self, sub: Subscription, reoffer, lost) -> bool:
        """Atomic leave: seal ``sub``, remove it, re-home its queued backlog.

        Under single delivery the departing member's queued messages are the
        ONLY copies, so they are re-offered to the surviving members via
        ``reoffer(member, item, tag)`` (the bus supplies wire conversion);
        unroutable items go to ``lost(item)``.  The whole hand-off holds the
        group lock, so concurrent ``pick()``s — publishes racing the
        departure — serialize *after* it: a rerouted backlog always lands
        ahead of newer messages on the new owner, which keeps per-key order
        intact across keyed rebalances.  Returns True if the group emptied.
        """
        with self._lock:
            # seal before drain: an in-flight publish that picked this member
            # just before the lock either enqueued already (drained below) or
            # is refused-and-counted after (offer/seal serialize on the
            # mailbox lock), then re-picks — blocking on the group lock until
            # this hand-off completes.
            sub._seal()
            pending = sub._drain_pending()
            self._remove_locked(sub)
            for tag, item in pending:
                self._unpick_tag_locked(tag)   # left the old mailbox
                while True:
                    member, tag2 = self._repick_locked(tag, item)
                    if member is None:
                        lost(item)
                        break
                    self.delivered += 1
                    if reoffer(member, item, tag2):
                        self.rerouted += 1
                        break
                    self.delivered -= 1
                    self._unpick_tag_locked(tag2)
            return not self.members

    # -- introspection ---------------------------------------------------------
    def _snapshot_locked(self) -> dict:
        nxt = self._next if self._next in self.members else None
        return {
            "policy": self.policy,
            "members": [m.name for m in self.members],
            "rr": self.members.index(nxt) if nxt is not None else 0,
            "delivered": self.delivered,
            "undeliverable": self.undeliverable,
            "rerouted": self.rerouted,
            "dropped": sum(m.dropped for m in self.members),
            "backlog": sum(m.qsize() for m in self.members),
            "replaying": [m.name for m in self.members if m.replaying],
            "steal_enabled": self.steal_enabled,
            "stolen": self.stolen,
            "steal_denied": self.steal_denied,
        }

    def snapshot(self) -> dict:
        with self._lock:
            return self._snapshot_locked()

    def backlog(self) -> int:
        """Group-aggregate mailbox depth (the pool's total queued work)."""
        with self._lock:
            return sum(m.qsize() for m in self.members)


class KeyedGroup(QueueGroup):
    """Hash-partitioned single delivery: every message for a key lands on the
    same healthy member (the ``keyed`` policy).

    ``key`` names the payload field to hash; its value maps to one of
    ``n_partitions`` partitions (:func:`partition_of`, blake2s), and the
    partition's owner is chosen by rendezvous hashing over the *healthy*
    members' names (:func:`partition_owner`).  Consequences:

    * same key -> same member while membership is unchanged (per-key order);
    * a leave moves exactly the leaver's partitions, each to its rendezvous
      runner-up; a join claims exactly the partitions the joiner wins —
      minimal disruption, so per-key state hand-off touches only the keys
      that actually move;
    * a departing member's backlog re-homes per partition (not round-robin),
      atomically with the membership change (:meth:`QueueGroup.depart`), so
      rebalances preserve per-key order end to end.

    An exact per-partition backlog is kept (incremented at delivery,
    decremented when the owning mailbox pops or evicts the item) — the
    autoscaler reads it to spot hot partitions that aggregate backlog hides.
    """

    policy = "keyed"

    def __init__(self, subject: str, name: str, key: str,
                 n_partitions: int = KEYED_PARTITIONS):
        super().__init__(subject, name)
        self.key = key
        self.n_partitions = n_partitions
        # dedicated lock: note_consumed is called from mailbox code paths
        # (some while holding a mailbox lock), and the main group lock is
        # held while *taking* mailbox locks in depart() — a shared lock
        # would deadlock.  This one is a leaf: it never takes another.
        self._pb_lock = threading.Lock()
        self._partition_backlog: dict[int, int] = {}
        # partitions orphaned by a member leaving a DURABLE subject: their
        # live traffic is parked on whichever member is replaying (the
        # recoverer adopts them) so the rendezvous runner-up cannot apply
        # new messages ahead of the leaver's unrecovered history.  Cleared
        # when the last replaying member catches up; discarded per partition
        # if traffic arrives while nobody is recovering.
        self._orphaned: set[int] = set()
        # assignment map memo, keyed on the healthy-member name tuple — the
        # ring is pure in membership, and recomputing it costs n_partitions
        # x members hashes, which sits on the autoscaler's metrics poll path
        self._ring_for: tuple[str, ...] | None = None
        self._ring: dict[int, str] = {}
        # partitions whose ownership migrated by work stealing: partition ->
        # thief member NAME, overriding the rendezvous ring so later messages
        # follow the stolen backlog (a key must never split across members).
        # Sticky until the named owner leaves (then the ring reclaims it with
        # the departure's ordered backlog hand-off) or is lazily found gone.
        self._stolen_owner: dict[int, str] = {}

    def add(self, sub: Subscription) -> None:
        with self._lock:
            if any(m.name == sub.name for m in self.members):
                # the ring routes by member NAME: a duplicate would collapse
                # both subscriptions onto one rendezvous identity and starve
                # every copy but the first — refuse loudly instead
                raise BusError(
                    f"keyed group {self.name!r} on {self.subject!r} already "
                    f"has a member named {sub.name!r}")
            self._add_locked(sub)
        sub._keyed_group = self

    def _healthy_names(self) -> list[str]:
        # Replaying members stay IN the keyed ring (unlike round-robin
        # groups, which skip them): moving their partitions away and back
        # would churn per-key state twice per recovery.  Live messages for
        # their partitions queue behind the replay — the pump serves log
        # batches first, and the cursor dedupe drops the mailbox overlap at
        # the flip, so history still cannot be interleaved or double-applied.
        return [m.name for m in self.members if not m.closed]

    def _ring_locked(self) -> dict[int, str]:
        """The memoized partition->owner-name map for the current healthy
        membership (pure in the name tuple, so a stale memo is impossible)."""
        names = tuple(self._healthy_names())
        if names != self._ring_for:
            self._ring = ring_assignment(names, self.n_partitions)
            self._ring_for = names
        return self._ring

    def _member_for_partition(self, p: int) -> Subscription | None:
        owner = self._ring_locked().get(p)
        if owner is None:
            return None
        for m in self.members:
            if m.name == owner and not m.closed:
                return m
        return None  # pragma: no cover - owner drawn from healthy names

    def _remove_locked(self, sub: Subscription) -> None:
        if sub in self.members and sub._log is not None:
            # durable subject: park the leaver's partitions until a
            # recoverer replays their history (see _orphaned above).  A
            # stolen partition belongs to its thief, not the ring — only
            # partitions the leaver actually owned are orphaned.
            names = [m.name for m in self.members
                     if m is sub or not m.closed]
            ring = ring_assignment(names, self.n_partitions)
            self._orphaned.update(
                p for p, owner in ring.items()
                if self._stolen_owner.get(p, owner) == sub.name)
        # drop steal overrides held by the leaver: depart()'s repick loop
        # then re-homes its drained backlog (stolen partitions included) by
        # the ring, in order, exactly like any other departure
        for p in [p for p, o in self._stolen_owner.items() if o == sub.name]:
            del self._stolen_owner[p]
        super()._remove_locked(sub)

    def _route_locked(self, p: int) -> Subscription | None:
        if p in self._orphaned:
            recoverer = next(
                (m for m in self.members if m.replaying and not m.closed),
                None)
            if recoverer is not None:
                return recoverer
            # nobody is recovering — hand the partition back to the ring
            # (availability over strict order, like drop-oldest mailboxes)
            self._orphaned.discard(p)
        owner = self._stolen_owner.get(p)
        if owner is not None:
            for m in self.members:
                if m.name == owner and not m.closed:
                    return m
            # thief vanished without a depart() (process death) — lazily
            # hand the partition back to the ring
            del self._stolen_owner[p]
        return self._member_for_partition(p)

    def _pick_locked(self, msg) -> tuple[Subscription | None, object]:
        payload = msg.payload if msg is not None else {}
        p = partition_of(payload.get(self.key), self.n_partitions)
        member = self._route_locked(p)
        if member is not None:
            with self._pb_lock:
                self._partition_backlog[p] = \
                    self._partition_backlog.get(p, 0) + 1
        return member, p

    def _repick_locked(self, tag, item) -> tuple[Subscription | None, object]:
        """Drained backlog keeps its partition: the item re-homes to the
        partition's NEW owner (the rendezvous runner-up — or the recovering
        member for an orphaned partition), never round-robin — that is what
        keeps all of a key's messages on one member."""
        if tag is None:  # pragma: no cover - keyed items are always tagged
            return None, None
        member = self._route_locked(tag)
        if member is not None:
            with self._pb_lock:
                self._partition_backlog[tag] = \
                    self._partition_backlog.get(tag, 0) + 1
        return member, tag

    def _unpick_tag_locked(self, tag) -> None:
        if tag is not None:
            self.note_consumed(tag)

    def note_consumed(self, tag) -> None:
        with self._pb_lock:
            left = self._partition_backlog.get(tag, 0) - 1
            if left > 0:
                self._partition_backlog[tag] = left
            else:
                self._partition_backlog.pop(tag, None)

    def note_requeued(self, tag) -> None:
        with self._pb_lock:
            self._partition_backlog[tag] = \
                self._partition_backlog.get(tag, 0) + 1

    def _steal_locked(self, thief: Subscription) -> tuple[int, bool]:
        """Partition-granular steal: move WHOLE queued partitions — heaviest
        first, up to half the victim's queue — never splitting a key.

        A partition is eligible only when the victim holds none of it in
        flight (its popped-but-unfinished burst, plus — for transport proxy
        subscriptions — tags shipped over the wire but unacked) and it is
        not orphaned awaiting durable recovery.  Chosen partitions' routing
        moves to the thief (``_stolen_owner``) under the same group lock, so
        every later message follows the stolen backlog: per-key order is
        victim-prefix then thief-suffix with no interleaving."""
        victim = self._deepest_victim_locked(thief)
        if victim is None:
            return 0, False
        q = victim._q
        with q.mutex:
            queued = list(q.queue)
            busy = set(victim._inflight_tags)
            ext = victim._external_inflight
            if ext is not None:
                busy |= set(ext())
            counts: dict[int, int] = {}
            for pair in queued:
                if pair is not None and pair[0] is not None:
                    counts[pair[0]] = counts.get(pair[0], 0) + 1
            eligible = [t for t in counts
                        if t not in busy and t not in self._orphaned]
            if not eligible:
                return 0, True
            eligible.sort(key=lambda t: counts[t], reverse=True)
            budget = max(1, len(queued) // 2)
            chosen: set[int] = set()
            total = 0
            for t in eligible:
                if chosen and total >= budget:
                    break
                chosen.add(t)
                total += counts[t]
            keep, taken = [], []
            for pair in queued:
                if pair is not None and pair[0] in chosen:
                    taken.append(pair)
                else:
                    keep.append(pair)
            q.queue.clear()
            q.queue.extend(keep)
        for t in chosen:
            self._stolen_owner[t] = thief.name
        self._transfer_locked(victim, thief, taken)
        return len(taken), True

    def _assignment_locked(self) -> dict[int, str]:
        return dict(self._ring_locked())

    def assignment(self) -> dict[int, str]:
        """The live partition->member map (healthy members only)."""
        with self._lock:
            return self._assignment_locked()

    def effective_assignment(self) -> dict[int, str]:
        """The ring WITH steal overrides applied — where a partition's
        messages actually route right now.  Replay filtering must use this
        (not :meth:`assignment`): a stolen partition's live copies go to the
        thief, so a recovering member serving them from the log would
        double-deliver."""
        with self._lock:
            ring = self._assignment_locked()
            live = {m.name for m in self.members if not m.closed}
            for p, owner in self._stolen_owner.items():
                if owner in live:
                    ring[p] = owner
            return ring

    def _snapshot_locked(self) -> dict:
        snap = super()._snapshot_locked()
        with self._pb_lock:
            pb = dict(self._partition_backlog)
        snap.update(
            key=self.key,
            n_partitions=self.n_partitions,
            assignment=self._assignment_locked(),
            partition_backlog=pb,
            stolen_partitions=dict(self._stolen_owner),
        )
        return snap


# ---------------------------------------------------------------------------
# The bus
# ---------------------------------------------------------------------------

def _resolve_replay_start(log: "DurableLog", replay_from) -> int:
    """A ``replay_from`` argument -> starting log offset.

    ``"snapshot"`` never reaches here: the operator resolves it against the
    stream's state database (``durable.resolve_replay_from``) before the
    sidecar subscribes."""
    if replay_from == "earliest":
        return log.earliest_offset()
    if replay_from == "snapshot":
        raise BusError(
            "replay_from='snapshot' must be resolved against the stream's "
            "state database first (durable.resolve_replay_from); the bus "
            "only accepts offsets, timestamps, or 'earliest'")
    if isinstance(replay_from, bool):
        raise BusError(f"bad replay_from {replay_from!r}")
    if isinstance(replay_from, int):
        return max(0, replay_from)
    if isinstance(replay_from, float):
        return log.offset_at_ts(replay_from)
    raise BusError(
        f"bad replay_from {replay_from!r}: expected an int offset, a float "
        f"timestamp, or 'earliest'")


class MessageBus:
    """Subject-based pub/sub with registration, authz, schema enforcement."""

    def __init__(self, default_queue_size: int = 256):
        self._lock = threading.RLock()
        self._subjects: dict[str, StreamSchema] = {}
        self._subs: dict[str, list[Subscription]] = {}
        self._groups: dict[str, dict[str, QueueGroup]] = {}  # subject -> name -> group
        self._tokens: dict[str, set[str] | None] = {}  # token -> allowed subjects (None=all)
        self._published: dict[str, int] = {}
        # messages that left the bus unconsumed when a departing group member
        # had no survivor to take its queued share (teardown/upgrade window);
        # kept on the SUBJECT so the loss stays visible in stats() after the
        # subscription itself is gone
        self._lost: dict[str, int] = {}
        self._durable: dict[str, "DurableLog"] = {}  # subject -> append log
        self._default_queue_size = default_queue_size
        self._closed = False

    # -- administration (called by the Operator, not by user code) ----------
    def register_subject(self, subject: str, schema: StreamSchema | None = None) -> None:
        """Create a subject (optionally schema-validated); publishing to or
        subscribing on an unregistered subject raises UnknownSubject."""
        with self._lock:
            if subject in self._subjects:
                raise BusError(f"subject {subject!r} already registered")
            self._subjects[subject] = schema or StreamSchema.untyped()
            self._subs[subject] = []
            self._groups[subject] = {}
            self._published[subject] = 0
            self._lost[subject] = 0

    def unregister_subject(self, subject: str) -> None:
        """Remove a subject, closing every subscription on it; a durable
        subject's log flushes and its on-disk history stays readable."""
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            for sub in self._subs.pop(subject):
                sub.close()
            self._groups.pop(subject, None)
            del self._subjects[subject]
            del self._published[subject]
            self._lost.pop(subject, None)
            log = self._durable.pop(subject, None)
        if log is not None:
            log.close()  # flush the tail; on-disk history stays readable

    def make_durable(self, subject: str, *,
                     retention: "Retention | dict | None" = None,
                     root: str | None = None,
                     **log_kwargs) -> "DurableLog":
        """Attach an append-only log to a registered subject (idempotent per
        subject is NOT supported — the operator declares durability exactly
        once, at stream/sensor registration).  From now on every publish
        appends before delivering and carries ``headers["offset"]``, and
        ``subscribe(replay_from=...)`` becomes legal on the subject."""
        from .durable import DurableLog
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            if subject in self._durable:
                raise BusError(f"subject {subject!r} is already durable")
            log = DurableLog(subject, retention=retention, root=root,
                             schema=self._subjects[subject], **log_kwargs)
            self._durable[subject] = log
            return log

    def durable_log(self, subject: str) -> "DurableLog | None":
        """The subject's append log, or None for fire-and-forget subjects."""
        with self._lock:
            return self._durable.get(subject)

    def subjects(self) -> list[str]:
        """All registered subject names, sorted."""
        with self._lock:
            return sorted(self._subjects)

    def schema_of(self, subject: str) -> StreamSchema:
        """The subject's declared :class:`StreamSchema` (untyped when none
        was registered); raises UnknownSubject for unregistered names."""
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            return self._subjects[subject]

    def issue_token(self, name: str, subjects: Iterable[str] | None = None) -> str:
        """Mint an auth token (None = platform token, allowed everywhere)."""
        token = f"tok-{name}-{len(self._tokens):04d}"
        with self._lock:
            self._tokens[token] = None if subjects is None else set(subjects)
        return token

    def revoke_token(self, token: str) -> None:
        """Invalidate a token; later publishes/subscribes with it raise
        Unauthorized (idempotent for unknown tokens)."""
        with self._lock:
            self._tokens.pop(token, None)

    def _authorize(self, token: str | None, subject: str) -> None:
        if token is None:
            raise Unauthorized("no token presented")
        with self._lock:
            if token not in self._tokens:
                raise Unauthorized(f"unknown token {token!r}")
            allowed = self._tokens[token]
        if allowed is not None and subject not in allowed:
            raise Unauthorized(f"token not authorized for subject {subject!r}")

    # -- data plane ----------------------------------------------------------
    def publish(self, subject: str, payload: dict, *, token: str,
                headers: dict | None = None) -> Message:
        """Publish one payload to a subject and deliver per policy:
        broadcast to ungrouped subscribers, one member per queue group
        (round-robin or keyed).  Validates authz + schema eagerly; on a
        durable subject the record is appended BEFORE delivery and the
        returned message carries ``headers["offset"]``.  Fire-and-forget:
        a message no subscriber could take is dropped (and counted)."""
        if self._closed:
            raise BusError("bus closed")
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            schema = self._subjects[subject]
            subs = list(self._subs[subject])
            groups = list(self._groups.get(subject, {}).values())
            log = self._durable.get(subject)
        self._authorize(token, subject)
        schema.validate(payload)
        msg = Message(subject=subject, payload=payload, headers=headers or {})
        if log is not None:
            # append BEFORE delivering: by the time any subscriber can see
            # this offset live, the log can serve it — the invariant the
            # gapless replay->live handoff rests on.  The offset rides the
            # message (and its wire encoding) so consumers can pair state
            # with log positions.
            msg.headers["offset"] = log.append(msg)
        self._deliver(msg, subs, groups)
        with self._lock:
            if subject in self._published:
                self._published[subject] += 1
        return msg

    def _deliver(self, msg: Message, subs: list[Subscription],
                 groups: Sequence[QueueGroup] = ()) -> None:
        """Fan out to every ungrouped subscription; ask each queue group's
        delivery policy (round-robin or keyed) for exactly one healthy
        member (single delivery per group).

        A refused offer (the picked member was sealed by a racing departure
        between our pick and the enqueue) re-picks, so the message still
        lands on a survivor whenever one exists."""
        wire_blob = None

        def offer(sub: Subscription, tag=None) -> bool:
            nonlocal wire_blob
            if sub.wire:
                if wire_blob is None:
                    wire_blob = encode_message(msg)
                return sub._offer(wire_blob, tag)
            return sub._offer(msg, tag)

        for sub in subs:
            if sub.group is None:
                offer(sub)
        for group in groups:
            while True:
                member, tag = group.pick(msg)
                if member is None:
                    break
                if offer(member, tag):
                    break
                group.unpick(tag)

    def note_lost(self, subject: str, n: int = 1) -> None:
        """Account ``n`` messages that were consumed from a mailbox but
        destroyed before processing completed (e.g. a poison message crashing
        its instance mid-``process``).  Under single delivery the popped copy
        was the only one, so without this the loss would be invisible in
        :meth:`stats` — the counter lives on the SUBJECT so it survives the
        crashed subscription."""
        with self._lock:
            if subject in self._lost:
                self._lost[subject] += n

    def subscribe(self, subject: str, *, token: str, maxsize: int | None = None,
                  wire: bool = False, name: str = "",
                  policy: DeliveryPolicy | None = None,
                  replay: ReplayFrom | None = None,
                  group: str | None = None, key: str | None = None,
                  partitions: int | None = None,
                  replay_from=None) -> Subscription:
        """``policy`` selects how this subject's messages reach the new
        subscription: :class:`~.delivery.Broadcast` (the default — every
        subscriber sees every message), :class:`~.delivery.Group` (a named
        single-delivery pool: each message goes to exactly one healthy
        member per group), or :class:`~.delivery.Keyed` (a group whose
        declared payload field is hashed onto a partition ring so every
        message for a key goes to the same member).  All members of one
        group must agree on the policy (and key).  The pre-policy kwargs —
        ``group=``, ``key=``, ``partitions=`` — still map onto these types,
        with a :class:`DeprecationWarning` per call site.

        ``replay`` (:class:`~.delivery.ReplayFrom`, durable subjects only)
        starts the subscription on the log instead of live —
        ``ReplayFrom.offset(n)`` / ``.timestamp(ts)`` / ``.earliest()``.
        ``next``/``next_batch`` serve history until the cursor reaches the
        head, then flip to live delivery — no gaps, no duplicates across
        the handoff.  The deprecated ``replay_from=`` raw values (int
        offset / float timestamp / ``"earliest"``) keep working."""
        steal = bool(getattr(policy, "steal", False))
        group, key, partitions = resolve_policy(policy, group, key,
                                                partitions)
        replay_from = resolve_replay(replay, replay_from)
        self._authorize(token, subject)
        if key is not None and group is None:
            raise BusError("keyed delivery needs a group name")
        if key is not None and partitions < 1:
            raise BusError(f"keyed delivery needs partitions >= 1, "
                           f"got {partitions}")
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            sub = Subscription(subject, maxsize or self._default_queue_size,
                               wire=wire, name=name, group=group)
            log = self._durable.get(subject)
            if replay_from is not None:
                if log is None:
                    raise BusError(
                        f"subject {subject!r} is not durable; replay_from "
                        f"requires make_durable (StreamSpec durable=True)")
                sub._log = log
                sub._cursor = _resolve_replay_start(log, replay_from)
                sub._replay_start = sub._replayed_upto = sub._cursor
                sub._join_head = log.next_offset()
                sub._replay_active = True
            elif log is not None:
                # live-from-head on a durable subject: the cursor still
                # tracks offsets so broadcast subscriptions heal drop-oldest
                # gaps from the log (the dedupe window stays empty)
                sub._log = log
                sub._cursor = log.next_offset()
                sub._replay_start = sub._replayed_upto = sub._cursor
                sub._join_head = sub._cursor
            if group is not None:
                g = self._groups[subject].get(group)
                if g is None:
                    g = (KeyedGroup(subject, group, key, partitions)
                         if key is not None else QueueGroup(subject, group))
                    self._groups[subject][group] = g
                elif key is not None and (g.policy != "keyed"
                                          or g.key != key):  # type: ignore[attr-defined]
                    raise BusError(
                        f"group {group!r} on {subject!r} is "
                        f"{g.policy}-delivery"
                        + (f" keyed on {g.key!r}" if g.policy == "keyed"
                           else "")
                        + f"; cannot join keyed on {key!r}")
                elif key is not None and partitions != g.n_partitions:  # type: ignore[attr-defined]
                    raise BusError(
                        f"group {group!r} on {subject!r} has "
                        f"{g.n_partitions} partitions; cannot join with "  # type: ignore[attr-defined]
                        f"partitions={partitions} (the ring size is fixed "
                        f"at group creation)")
                elif key is None and g.policy == "keyed":
                    raise BusError(
                        f"group {group!r} on {subject!r} is keyed on "
                        f"{g.key!r}; members must subscribe with key=")  # type: ignore[attr-defined]
                g.add(sub)
                sub._group_ref = g
                if steal:
                    # first steal=True member switches the whole pool on —
                    # stealing is a group property (all mailboxes are fair
                    # game), not a per-member one
                    g.steal_enabled = True
            self._subs[subject].append(sub)
            return sub

    def enable_stealing(self, subject: str, group: str) -> bool:
        """Switch pull-based work stealing on for an EXISTING queue group
        (the runtime equivalent of the first member joining with
        ``Group(..., steal=True)``) — stealing is a pool property, so one
        switch covers every member's mailbox.  Returns False when no such
        group exists yet (join a member first)."""
        with self._lock:
            g = self._groups.get(subject, {}).get(group)
            if g is None:
                return False
            g.steal_enabled = True
            return True

    def unsubscribe(self, sub: Subscription) -> None:
        """Close a subscription and leave its group; a group member's
        queued backlog re-homes atomically to surviving members (per-key
        order preserved for keyed groups)."""
        g: QueueGroup | None = None
        with self._lock:
            subs = self._subs.get(sub.subject)
            if subs and sub in subs:
                subs.remove(sub)
            if sub.group is not None:
                g = self._groups.get(sub.subject, {}).get(sub.group)
        if g is not None:
            # single delivery: the departing member's queued messages are the
            # ONLY copies — the group's depart() re-homes them to survivors
            # (round-robin for plain groups, per-partition for keyed ones)
            # atomically with the membership change, so rerouted backlog
            # always precedes newer messages on the new owner.
            lost_count = [0]

            def reoffer(member: Subscription, item, tag) -> bool:
                is_wire = isinstance(item, (bytes, bytearray))
                if member.wire == is_wire:
                    return member._offer(item, tag)
                if member.wire:
                    return member._offer(encode_message(item), tag)
                return member._offer(decode_message(item), tag)

            def lost(item) -> None:
                # last member out (stream teardown / upgrade window): the
                # share is lost — counted on the mailbox AND (below, outside
                # the group lock) on the subject, so the loss outlives the
                # subscription in stats() instead of vanishing with it
                sub.dropped += 1
                lost_count[0] += 1

            emptied = g.depart(sub, reoffer, lost)
            with self._lock:
                if lost_count[0] and sub.subject in self._lost:
                    self._lost[sub.subject] += lost_count[0]
                if emptied:
                    groups = self._groups.get(sub.subject, {})
                    # re-check under the bus lock: a new member may have
                    # joined between depart() and here
                    if groups.get(sub.group) is g and g.is_empty():
                        del groups[sub.group]
        sub.close()

    # -- introspection --------------------------------------------------------
    def stats(self) -> dict:
        """Per-subject counters, including per-group membership / round-robin
        position / drop counts and a per-subscription drop breakdown (drops
        mean a consumer is losing data — the autoscaler treats them as a hard
        scale-up signal)."""
        with self._lock:
            return {
                subject: {
                    "published": self._published[subject],
                    "subscribers": len(self._subs[subject]),
                    "backlog": sum(s.qsize() for s in self._subs[subject]),
                    "dropped": sum(s.dropped for s in self._subs[subject]),
                    "lost": self._lost.get(subject, 0),
                    "durable": (self._durable[subject].info()
                                if subject in self._durable else None),
                    "groups": {name: g.snapshot()
                               for name, g in
                               self._groups.get(subject, {}).items()},
                    "subscriptions": {
                        s.name: {"group": s.group, "backlog": s.qsize(),
                                 "received": s.received, "dropped": s.dropped,
                                 "replaying": s.replaying,
                                 "replayed": s.replayed,
                                 "replay_lag": s.replay_lag(),
                                 "deduped": s.deduped, "healed": s.healed}
                        for s in self._subs[subject]
                    },
                }
                for subject in self._subjects
            }

    def group_info(self, subject: str, group: str) -> dict | None:
        """Snapshot of one queue group (delivery policy, members, delivered,
        backlog-as-lag; plus key/assignment/partition_backlog when keyed) —
        the sidecar surfaces this through its REST metrics."""
        with self._lock:
            g = self._groups.get(subject, {}).get(group)
        return g.snapshot() if g is not None else None

    def backlog(self, subject: str) -> int:
        """Deepest consumer lag on ``subject``: max over ungrouped mailbox
        depths and group-aggregate depths (a group's lag is the SUM of its
        members' mailboxes — the pool shares one logical queue)."""
        with self._lock:
            subs = self._subs.get(subject, [])
            solo = max((s.qsize() for s in subs if s.group is None), default=0)
            pooled = max((g.backlog()
                          for g in self._groups.get(subject, {}).values()),
                         default=0)
            return max(solo, pooled)

    def close(self) -> None:
        """Shut the bus down: refuse further publishes, close every
        subscription, flush root-backed durable-log tails to disk."""
        with self._lock:
            self._closed = True
            for subs in self._subs.values():
                for s in subs:
                    s.close()
            logs = list(self._durable.values())
        for log in logs:
            log.close()  # flush root-backed tails


def drain(sub: Subscription, n: int, timeout: float = 5.0) -> list[Message]:
    """Test helper: pop n messages or raise."""
    out = []
    deadline = time.monotonic() + timeout
    while len(out) < n:
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise TimeoutError(f"drained {len(out)}/{n} from {sub.subject}")
        msg = sub.next(timeout=remaining)
        if msg is not None:
            out.append(msg)
    return out
