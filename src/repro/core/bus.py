"""MessageBus — the NATS analog (paper §4, "Message bus").

Subject-based pub/sub with:

* **registration + authorization** — "only services deployed on DataX will be
  able to connect ... they will be able to subscribe and publish only on the
  defined and registered streams."  Publishing to an unregistered subject, or
  with a token that is not authorized for that subject, raises.
* **bounded subscriber queues** with a drop-oldest policy (streams are lossy
  real-time flows; the sidecar counts drops and reports them as metrics).
* **schema enforcement** — each subject carries a StreamSchema; publishes are
  validated against it (homogeneous streams, §2).
* **wire serialization** — msgpack (+numpy) encode/decode used when a message
  crosses a host boundary.  In-process delivery passes payloads by reference;
  ``wire=True`` subscriptions force the encode/decode round-trip, which tests
  use to prove payloads are wire-safe.

This is deliberately an in-process bus: the container is one host.  The class
is factored so a NATS-backed implementation only replaces ``_deliver``.
"""
from __future__ import annotations

import io
import queue
import threading
import time
from typing import Callable, Iterable

import msgpack
import numpy as np

from .schema import Message, StreamSchema


# ---------------------------------------------------------------------------
# Wire format: msgpack with an extension for numpy arrays
# ---------------------------------------------------------------------------

_NDARRAY_EXT = 42


def _default(obj):
    if isinstance(obj, np.ndarray):
        buf = io.BytesIO()
        np.save(buf, obj, allow_pickle=False)
        return msgpack.ExtType(_NDARRAY_EXT, buf.getvalue())
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot serialize {type(obj).__name__} on the wire")


def _ext_hook(code, data):
    if code == _NDARRAY_EXT:
        return np.load(io.BytesIO(data), allow_pickle=False)
    return msgpack.ExtType(code, data)


def encode_payload(payload: dict) -> bytes:
    return msgpack.packb(payload, default=_default, use_bin_type=True)


def decode_payload(raw: bytes) -> dict:
    return msgpack.unpackb(raw, ext_hook=_ext_hook, raw=False, strict_map_key=False)


def encode_message(msg: Message) -> bytes:
    return msgpack.packb(
        {"subject": msg.subject, "seq": msg.seq, "ts": msg.ts,
         "headers": msg.headers, "payload": msg.payload},
        default=_default, use_bin_type=True)


def decode_message(raw: bytes) -> Message:
    d = msgpack.unpackb(raw, ext_hook=_ext_hook, raw=False, strict_map_key=False)
    return Message(subject=d["subject"], payload=d["payload"], seq=d["seq"],
                   ts=d["ts"], headers=d.get("headers", {}))


# ---------------------------------------------------------------------------
# Errors
# ---------------------------------------------------------------------------

class BusError(RuntimeError):
    pass


class Unauthorized(BusError):
    pass


class UnknownSubject(BusError):
    pass


# ---------------------------------------------------------------------------
# Subscriptions
# ---------------------------------------------------------------------------

class Subscription:
    """A bounded mailbox bound to one subject."""

    def __init__(self, subject: str, maxsize: int, wire: bool, name: str = ""):
        self.subject = subject
        self.name = name or f"sub-{id(self):x}"
        self.wire = wire
        self._q: queue.Queue = queue.Queue(maxsize=maxsize)
        self.received = 0
        self.dropped = 0
        self.closed = False
        self._lock = threading.Lock()

    def _offer(self, item) -> None:
        """Enqueue with drop-oldest on overflow (lossy stream semantics)."""
        with self._lock:
            if self.closed:
                return
            while True:
                try:
                    self._q.put_nowait(item)
                    self.received += 1
                    return
                except queue.Full:
                    try:
                        self._q.get_nowait()
                        self.dropped += 1
                    except queue.Empty:  # pragma: no cover - race guard
                        pass

    def next(self, timeout: float | None = None) -> Message | None:
        """Blocking pop; None on timeout or close."""
        try:
            item = self._q.get(timeout=timeout)
        except queue.Empty:
            return None
        if item is None:
            return None
        if self.wire:
            return decode_message(item)
        return item

    def qsize(self) -> int:
        return self._q.qsize()

    def close(self) -> None:
        with self._lock:
            self.closed = True
            # Wake blocked readers.  If the mailbox is full, evict one item so
            # the sentinel always lands — otherwise a reader blocked in next()
            # would never observe the close.
            while True:
                try:
                    self._q.put_nowait(None)
                    return
                except queue.Full:
                    try:
                        self._q.get_nowait()
                        self.dropped += 1
                    except queue.Empty:  # pragma: no cover - race guard
                        pass


# ---------------------------------------------------------------------------
# The bus
# ---------------------------------------------------------------------------

class MessageBus:
    """Subject-based pub/sub with registration, authz, schema enforcement."""

    def __init__(self, default_queue_size: int = 256):
        self._lock = threading.RLock()
        self._subjects: dict[str, StreamSchema] = {}
        self._subs: dict[str, list[Subscription]] = {}
        self._tokens: dict[str, set[str] | None] = {}  # token -> allowed subjects (None=all)
        self._published: dict[str, int] = {}
        self._default_queue_size = default_queue_size
        self._closed = False

    # -- administration (called by the Operator, not by user code) ----------
    def register_subject(self, subject: str, schema: StreamSchema | None = None) -> None:
        with self._lock:
            if subject in self._subjects:
                raise BusError(f"subject {subject!r} already registered")
            self._subjects[subject] = schema or StreamSchema.untyped()
            self._subs[subject] = []
            self._published[subject] = 0

    def unregister_subject(self, subject: str) -> None:
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            for sub in self._subs.pop(subject):
                sub.close()
            del self._subjects[subject]
            del self._published[subject]

    def subjects(self) -> list[str]:
        with self._lock:
            return sorted(self._subjects)

    def schema_of(self, subject: str) -> StreamSchema:
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            return self._subjects[subject]

    def issue_token(self, name: str, subjects: Iterable[str] | None = None) -> str:
        """Mint an auth token (None = platform token, allowed everywhere)."""
        token = f"tok-{name}-{len(self._tokens):04d}"
        with self._lock:
            self._tokens[token] = None if subjects is None else set(subjects)
        return token

    def revoke_token(self, token: str) -> None:
        with self._lock:
            self._tokens.pop(token, None)

    def _authorize(self, token: str | None, subject: str) -> None:
        if token is None:
            raise Unauthorized("no token presented")
        with self._lock:
            if token not in self._tokens:
                raise Unauthorized(f"unknown token {token!r}")
            allowed = self._tokens[token]
        if allowed is not None and subject not in allowed:
            raise Unauthorized(f"token not authorized for subject {subject!r}")

    # -- data plane ----------------------------------------------------------
    def publish(self, subject: str, payload: dict, *, token: str,
                headers: dict | None = None) -> Message:
        if self._closed:
            raise BusError("bus closed")
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            schema = self._subjects[subject]
            subs = list(self._subs[subject])
        self._authorize(token, subject)
        schema.validate(payload)
        msg = Message(subject=subject, payload=payload, headers=headers or {})
        self._deliver(msg, subs)
        with self._lock:
            if subject in self._published:
                self._published[subject] += 1
        return msg

    def _deliver(self, msg: Message, subs: list[Subscription]) -> None:
        wire_blob = None
        for sub in subs:
            if sub.wire:
                if wire_blob is None:
                    wire_blob = encode_message(msg)
                sub._offer(wire_blob)
            else:
                sub._offer(msg)

    def subscribe(self, subject: str, *, token: str, maxsize: int | None = None,
                  wire: bool = False, name: str = "") -> Subscription:
        self._authorize(token, subject)
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            sub = Subscription(subject, maxsize or self._default_queue_size,
                               wire=wire, name=name)
            self._subs[subject].append(sub)
            return sub

    def unsubscribe(self, sub: Subscription) -> None:
        with self._lock:
            subs = self._subs.get(sub.subject)
            if subs and sub in subs:
                subs.remove(sub)
        sub.close()

    # -- introspection --------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {
                subject: {
                    "published": self._published[subject],
                    "subscribers": len(self._subs[subject]),
                    "backlog": sum(s.qsize() for s in self._subs[subject]),
                    "dropped": sum(s.dropped for s in self._subs[subject]),
                }
                for subject in self._subjects
            }

    def backlog(self, subject: str) -> int:
        with self._lock:
            subs = self._subs.get(subject, [])
            return max((s.qsize() for s in subs), default=0)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            for subs in self._subs.values():
                for s in subs:
                    s.close()


def drain(sub: Subscription, n: int, timeout: float = 5.0) -> list[Message]:
    """Test helper: pop n messages or raise."""
    out = []
    deadline = time.monotonic() + timeout
    while len(out) < n:
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise TimeoutError(f"drained {len(out)}/{n} from {sub.subject}")
        msg = sub.next(timeout=remaining)
        if msg is not None:
            out.append(msg)
    return out
