"""MessageBus — the NATS analog (paper §4, "Message bus").

Subject-based pub/sub with:

* **registration + authorization** — "only services deployed on DataX will be
  able to connect ... they will be able to subscribe and publish only on the
  defined and registered streams."  Publishing to an unregistered subject, or
  with a token that is not authorized for that subject, raises.
* **bounded subscriber queues** with a drop-oldest policy (streams are lossy
  real-time flows; the sidecar counts drops and reports them as metrics).
* **queue groups** (the NATS queue-group analog) — ``subscribe(...,
  group="owner")`` joins a named single-delivery group on the subject: each
  message is round-robined to exactly ONE healthy member per group, while
  still fanning out to every ungrouped subscription and to every *other*
  group.  Scaled instances of the same stream join one group (a worker pool,
  N instances = N× capacity); different consumer streams use different group
  names, so §3 multi-app stream reuse keeps broadcast semantics.
* **schema enforcement** — each subject carries a StreamSchema; publishes are
  validated against it (homogeneous streams, §2).
* **wire serialization** — msgpack (+numpy) encode/decode used when a message
  crosses a host boundary.  In-process delivery passes payloads by reference;
  ``wire=True`` subscriptions force the encode/decode round-trip, which tests
  use to prove payloads are wire-safe.

This is deliberately an in-process bus: the container is one host.  The class
is factored so a NATS-backed implementation only replaces ``_deliver``.
"""
from __future__ import annotations

import io
import queue
import threading
import time
from typing import Iterable, Sequence

import msgpack
import numpy as np

from .schema import Message, StreamSchema


# ---------------------------------------------------------------------------
# Wire format: msgpack with an extension for numpy arrays
# ---------------------------------------------------------------------------

_NDARRAY_EXT = 42


def _default(obj):
    if isinstance(obj, np.ndarray):
        buf = io.BytesIO()
        np.save(buf, obj, allow_pickle=False)
        return msgpack.ExtType(_NDARRAY_EXT, buf.getvalue())
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot serialize {type(obj).__name__} on the wire")


def _ext_hook(code, data):
    if code == _NDARRAY_EXT:
        return np.load(io.BytesIO(data), allow_pickle=False)
    return msgpack.ExtType(code, data)


def encode_payload(payload: dict) -> bytes:
    return msgpack.packb(payload, default=_default, use_bin_type=True)


def decode_payload(raw: bytes) -> dict:
    return msgpack.unpackb(raw, ext_hook=_ext_hook, raw=False, strict_map_key=False)


def encode_message(msg: Message) -> bytes:
    return msgpack.packb(
        {"subject": msg.subject, "seq": msg.seq, "ts": msg.ts,
         "headers": msg.headers, "payload": msg.payload},
        default=_default, use_bin_type=True)


def decode_message(raw: bytes) -> Message:
    d = msgpack.unpackb(raw, ext_hook=_ext_hook, raw=False, strict_map_key=False)
    return Message(subject=d["subject"], payload=d["payload"], seq=d["seq"],
                   ts=d["ts"], headers=d.get("headers", {}))


# ---------------------------------------------------------------------------
# Errors
# ---------------------------------------------------------------------------

class BusError(RuntimeError):
    pass


class Unauthorized(BusError):
    pass


class UnknownSubject(BusError):
    pass


# ---------------------------------------------------------------------------
# Subscriptions
# ---------------------------------------------------------------------------

class Subscription:
    """A bounded mailbox bound to one subject.

    ``group`` is the queue-group name this subscription joined (None =
    ungrouped broadcast subscriber).  Drops are counted per subscription and
    surfaced through ``MessageBus.stats()`` — a nonzero count means this
    consumer is losing data and is a hard scale-up signal for the autoscaler.
    """

    def __init__(self, subject: str, maxsize: int, wire: bool, name: str = "",
                 group: str | None = None):
        self.subject = subject
        self.name = name or f"sub-{id(self):x}"
        self.wire = wire
        self.group = group
        self._q: queue.Queue = queue.Queue(maxsize=maxsize)
        self.received = 0
        self.dropped = 0
        self.closed = False
        self._lock = threading.Lock()

    def _offer(self, item) -> bool:
        """Enqueue with drop-oldest on overflow (lossy stream semantics).

        Returns False when the mailbox is closed (counted as a drop here so
        the refusal is never silent; a group-delivery caller re-picks another
        member so the message still reaches a survivor)."""
        with self._lock:
            if self.closed:
                self.dropped += 1
                return False
            while True:
                try:
                    self._q.put_nowait(item)
                    self.received += 1
                    return True
                except queue.Full:
                    try:
                        self._q.get_nowait()
                        self.dropped += 1
                    except queue.Empty:  # pragma: no cover - race guard
                        pass

    def next(self, timeout: float | None = None) -> Message | None:
        """Blocking pop; None on timeout or close."""
        try:
            item = self._q.get(timeout=timeout)
        except queue.Empty:
            return None
        if item is None:
            return None
        if self.wire:
            return decode_message(item)
        return item

    def qsize(self) -> int:
        return self._q.qsize()

    def _seal(self) -> None:
        """Mark closed WITHOUT waking readers (no sentinel, no eviction).

        Departing-group-member hand-off step 1: once sealed, every further
        ``_offer`` is refused and counted, so a publisher that picked this
        member just before it left the rotation cannot slip a message in
        after the backlog drain (offer and seal serialize on the mailbox
        lock).  ``close()`` afterwards still delivers the reader sentinel.
        """
        with self._lock:
            self.closed = True

    def _drain_pending(self) -> list:
        """Pop everything still queued (raw items, possibly wire blobs).

        Used when a group member departs: under single delivery its queued
        messages are the only copies, so the bus hands them to the surviving
        members instead of garbage-collecting them.  Call after ``_seal()``.
        """
        items = []
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                return items
            if item is not None:
                items.append(item)

    def close(self) -> None:
        with self._lock:
            self.closed = True
            # Wake blocked readers.  If the mailbox is full, evict one item so
            # the sentinel always lands — otherwise a reader blocked in next()
            # would never observe the close.
            while True:
                try:
                    self._q.put_nowait(None)
                    return
                except queue.Full:
                    try:
                        self._q.get_nowait()
                        self.dropped += 1
                    except queue.Empty:  # pragma: no cover - race guard
                        pass


class QueueGroup:
    """A named single-delivery group on one subject (NATS queue-group analog).

    Members are Subscriptions; ``pick()`` advances a round-robin cursor and
    returns the next *healthy* (non-closed) member, skipping dead ones so a
    member dying mid-rotation re-routes its share to the survivors.  Membership
    changes happen under the bus lock; the group's own lock orders ``pick()``
    against them (lock order is always bus → group, so no deadlock).
    """

    def __init__(self, subject: str, name: str):
        self.subject = subject
        self.name = name
        self.members: list[Subscription] = []
        self.rr = 0                   # round-robin cursor (next member index)
        self.delivered = 0            # hand-offs to a member (incl. re-routes)
        self.undeliverable = 0        # published while no healthy member
        self.rerouted = 0             # departing-member backlog re-deliveries
        self._lock = threading.Lock()

    def add(self, sub: Subscription) -> None:
        with self._lock:
            self.members.append(sub)

    def remove(self, sub: Subscription) -> bool:
        """Remove a member; True if the group is now empty."""
        with self._lock:
            if sub in self.members:
                i = self.members.index(sub)
                self.members.remove(sub)
                if i < self.rr:
                    self.rr -= 1     # keep the cursor on the same successor
                if self.members:
                    self.rr %= len(self.members)
                else:
                    self.rr = 0
            return not self.members

    def pick(self) -> Subscription | None:
        with self._lock:
            n = len(self.members)
            for i in range(n):
                m = self.members[(self.rr + i) % n]
                if not m.closed:
                    self.rr = (self.rr + i + 1) % n
                    self.delivered += 1
                    return m
            self.undeliverable += 1
            return None

    def note_reroute(self) -> None:
        with self._lock:
            self.rerouted += 1

    def unpick(self) -> None:
        """Roll back a pick() whose offer was refused (member sealed by a
        racing departure) so ``delivered`` stays exact before the re-pick."""
        with self._lock:
            self.delivered -= 1

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "members": [m.name for m in self.members],
                "rr": self.rr,
                "delivered": self.delivered,
                "undeliverable": self.undeliverable,
                "rerouted": self.rerouted,
                "dropped": sum(m.dropped for m in self.members),
                "backlog": sum(m.qsize() for m in self.members),
            }

    def backlog(self) -> int:
        """Group-aggregate mailbox depth (the pool's total queued work)."""
        with self._lock:
            return sum(m.qsize() for m in self.members)


# ---------------------------------------------------------------------------
# The bus
# ---------------------------------------------------------------------------

class MessageBus:
    """Subject-based pub/sub with registration, authz, schema enforcement."""

    def __init__(self, default_queue_size: int = 256):
        self._lock = threading.RLock()
        self._subjects: dict[str, StreamSchema] = {}
        self._subs: dict[str, list[Subscription]] = {}
        self._groups: dict[str, dict[str, QueueGroup]] = {}  # subject -> name -> group
        self._tokens: dict[str, set[str] | None] = {}  # token -> allowed subjects (None=all)
        self._published: dict[str, int] = {}
        # messages that left the bus unconsumed when a departing group member
        # had no survivor to take its queued share (teardown/upgrade window);
        # kept on the SUBJECT so the loss stays visible in stats() after the
        # subscription itself is gone
        self._lost: dict[str, int] = {}
        self._default_queue_size = default_queue_size
        self._closed = False

    # -- administration (called by the Operator, not by user code) ----------
    def register_subject(self, subject: str, schema: StreamSchema | None = None) -> None:
        with self._lock:
            if subject in self._subjects:
                raise BusError(f"subject {subject!r} already registered")
            self._subjects[subject] = schema or StreamSchema.untyped()
            self._subs[subject] = []
            self._groups[subject] = {}
            self._published[subject] = 0
            self._lost[subject] = 0

    def unregister_subject(self, subject: str) -> None:
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            for sub in self._subs.pop(subject):
                sub.close()
            self._groups.pop(subject, None)
            del self._subjects[subject]
            del self._published[subject]
            self._lost.pop(subject, None)

    def subjects(self) -> list[str]:
        with self._lock:
            return sorted(self._subjects)

    def schema_of(self, subject: str) -> StreamSchema:
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            return self._subjects[subject]

    def issue_token(self, name: str, subjects: Iterable[str] | None = None) -> str:
        """Mint an auth token (None = platform token, allowed everywhere)."""
        token = f"tok-{name}-{len(self._tokens):04d}"
        with self._lock:
            self._tokens[token] = None if subjects is None else set(subjects)
        return token

    def revoke_token(self, token: str) -> None:
        with self._lock:
            self._tokens.pop(token, None)

    def _authorize(self, token: str | None, subject: str) -> None:
        if token is None:
            raise Unauthorized("no token presented")
        with self._lock:
            if token not in self._tokens:
                raise Unauthorized(f"unknown token {token!r}")
            allowed = self._tokens[token]
        if allowed is not None and subject not in allowed:
            raise Unauthorized(f"token not authorized for subject {subject!r}")

    # -- data plane ----------------------------------------------------------
    def publish(self, subject: str, payload: dict, *, token: str,
                headers: dict | None = None) -> Message:
        if self._closed:
            raise BusError("bus closed")
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            schema = self._subjects[subject]
            subs = list(self._subs[subject])
            groups = list(self._groups.get(subject, {}).values())
        self._authorize(token, subject)
        schema.validate(payload)
        msg = Message(subject=subject, payload=payload, headers=headers or {})
        self._deliver(msg, subs, groups)
        with self._lock:
            if subject in self._published:
                self._published[subject] += 1
        return msg

    def _deliver(self, msg: Message, subs: list[Subscription],
                 groups: Sequence[QueueGroup] = ()) -> None:
        """Fan out to every ungrouped subscription; round-robin each queue
        group to exactly one healthy member (single delivery per group).

        A refused offer (the picked member was sealed by a racing departure
        between our pick and the enqueue) re-picks, so the message still
        lands on a survivor whenever one exists."""
        wire_blob = None

        def offer(sub: Subscription) -> bool:
            nonlocal wire_blob
            if sub.wire:
                if wire_blob is None:
                    wire_blob = encode_message(msg)
                return sub._offer(wire_blob)
            return sub._offer(msg)

        for sub in subs:
            if sub.group is None:
                offer(sub)
        for group in groups:
            while True:
                member = group.pick()
                if member is None:
                    break
                if offer(member):
                    break
                group.unpick()

    def subscribe(self, subject: str, *, token: str, maxsize: int | None = None,
                  wire: bool = False, name: str = "",
                  group: str | None = None) -> Subscription:
        """``group`` joins the named queue group on this subject: each message
        goes to exactly one healthy member of each group (round-robin), while
        ungrouped subscriptions keep broadcast semantics."""
        self._authorize(token, subject)
        with self._lock:
            if subject not in self._subjects:
                raise UnknownSubject(subject)
            sub = Subscription(subject, maxsize or self._default_queue_size,
                               wire=wire, name=name, group=group)
            self._subs[subject].append(sub)
            if group is not None:
                g = self._groups[subject].setdefault(
                    group, QueueGroup(subject, group))
                g.add(sub)
            return sub

    def unsubscribe(self, sub: Subscription) -> None:
        grouped = False
        survivors: QueueGroup | None = None
        with self._lock:
            subs = self._subs.get(sub.subject)
            if subs and sub in subs:
                subs.remove(sub)
            if sub.group is not None:
                groups = self._groups.get(sub.subject, {})
                g = groups.get(sub.group)
                if g is not None:
                    grouped = True
                    if g.remove(sub):
                        del groups[sub.group]
                    else:
                        survivors = g
        if grouped:
            # single delivery: the departing member's queued messages are the
            # ONLY copies — hand them to the survivors.  Seal first: an
            # in-flight publish that picked this member just before it left
            # the rotation either enqueued before the seal (drained below) or
            # is refused-and-counted after it; offers and the seal serialize
            # on the mailbox lock, so nothing slips in post-drain.
            sub._seal()
            for item in sub._drain_pending():
                while True:
                    member = survivors.pick() if survivors is not None else None
                    if member is None:
                        # last member out (stream teardown / upgrade window):
                        # the share is lost — counted on the mailbox AND on
                        # the subject, so the loss outlives the subscription
                        # in stats() instead of vanishing with it
                        sub.dropped += 1
                        with self._lock:
                            if sub.subject in self._lost:
                                self._lost[sub.subject] += 1
                        break
                    is_wire = isinstance(item, (bytes, bytearray))
                    if member.wire == is_wire:
                        ok = member._offer(item)
                    elif member.wire:
                        ok = member._offer(encode_message(item))
                    else:
                        ok = member._offer(decode_message(item))
                    if ok:
                        survivors.note_reroute()
                        break
                    survivors.unpick()
        sub.close()

    # -- introspection --------------------------------------------------------
    def stats(self) -> dict:
        """Per-subject counters, including per-group membership / round-robin
        position / drop counts and a per-subscription drop breakdown (drops
        mean a consumer is losing data — the autoscaler treats them as a hard
        scale-up signal)."""
        with self._lock:
            return {
                subject: {
                    "published": self._published[subject],
                    "subscribers": len(self._subs[subject]),
                    "backlog": sum(s.qsize() for s in self._subs[subject]),
                    "dropped": sum(s.dropped for s in self._subs[subject]),
                    "lost": self._lost.get(subject, 0),
                    "groups": {name: g.snapshot()
                               for name, g in
                               self._groups.get(subject, {}).items()},
                    "subscriptions": {
                        s.name: {"group": s.group, "backlog": s.qsize(),
                                 "received": s.received, "dropped": s.dropped}
                        for s in self._subs[subject]
                    },
                }
                for subject in self._subjects
            }

    def backlog(self, subject: str) -> int:
        """Deepest consumer lag on ``subject``: max over ungrouped mailbox
        depths and group-aggregate depths (a group's lag is the SUM of its
        members' mailboxes — the pool shares one logical queue)."""
        with self._lock:
            subs = self._subs.get(subject, [])
            solo = max((s.qsize() for s in subs if s.group is None), default=0)
            pooled = max((g.backlog()
                          for g in self._groups.get(subject, {}).values()),
                         default=0)
            return max(solo, pooled)

    def close(self) -> None:
        with self._lock:
            self._closed = True
            for subs in self._subs.values():
                for s in subs:
                    s.close()


def drain(sub: Subscription, n: int, timeout: float = 5.0) -> list[Message]:
    """Test helper: pop n messages or raise."""
    out = []
    deadline = time.monotonic() + timeout
    while len(out) < n:
        remaining = deadline - time.monotonic()
        if remaining <= 0:
            raise TimeoutError(f"drained {len(out)}/{n} from {sub.subject}")
        msg = sub.next(timeout=remaining)
        if msg is not None:
            out.append(msg)
    return out
