"""DataX entity specs — the custom resources of §2/§4.

Seven entity kinds, mirroring the paper's CRDs: driver, analytics unit (AU),
actuator (the three *code* entities, registered with business logic), and
sensor, stream, gadget, database (the *instance* entities that reference them).

Code entities carry a ``logic`` factory (the paper's "script or docker image")
and a :class:`ConfigSchema`.  Instance entities carry a config validated
against the code entity's schema by the Operator at registration time.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Mapping, Sequence

from .schema import ConfigSchema, StreamSchema


class EntityKind(str, enum.Enum):
    DRIVER = "driver"
    ANALYTICS_UNIT = "analytics_unit"
    ACTUATOR = "actuator"
    SENSOR = "sensor"
    STREAM = "stream"
    GADGET = "gadget"
    DATABASE = "database"


class Placement(str, enum.Enum):
    """Where an AU's logic executes.

    HOST   — a python callable run by worker threads (classic DataX).
    DEVICE — a jitted JAX program on the mesh; the operator lowers its stream
             edges to pjit shardings instead of bus hops (TPU adaptation).
    """

    HOST = "host"
    DEVICE = "device"


# ---------------------------------------------------------------------------
# Code entities
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DriverSpec:
    """Generates a stream from a sensor (paper: 'business logic ... a driver')."""

    name: str
    logic: Callable[..., Any]            # factory: (ctx) -> iterator/callable
    config_schema: ConfigSchema = dataclasses.field(default_factory=ConfigSchema.empty)
    output_schema: StreamSchema = dataclasses.field(default_factory=StreamSchema.untyped)
    version: int = 1
    node_affinity: str | None = None     # e.g. "usb:host3" — the paper's USB pinning

    kind = EntityKind.DRIVER


@dataclasses.dataclass(frozen=True)
class AnalyticsUnitSpec:
    """Transforms/fuses input streams into an output stream (paper §2).

    Device lowering fields:

    * ``pure_fn`` — the raw, side-effect-free payload function behind a DSL
      combinator (``.map(fn, device=True)`` sets it to ``fn``).  The fusion
      pass composes consecutive pure_fns into one ``jax.jit`` program; an AU
      without one still fuses, but the segment executes host-composed.
    * ``combinator`` — non-empty for synthetic DSL combinator AUs
      ("map"/"filter"/"window"/"fuse"); the fusion pass may garbage-collect
      synthetic AUs whose only stream was folded into a fused segment.
    * ``fused_stages`` — non-empty marks a *fused* AU produced by the chain
      fusion pass; it lists the stage AU names folded in, in chain order.
      The Operator autoscales a fused unit as a whole (one microservice for
      the whole segment) instead of skipping DEVICE placements.
    """

    name: str
    logic: Callable[..., Any]            # factory: (ctx) -> process(payloads)->payload
    config_schema: ConfigSchema = dataclasses.field(default_factory=ConfigSchema.empty)
    input_schemas: Sequence[StreamSchema] = ()
    output_schema: StreamSchema = dataclasses.field(default_factory=StreamSchema.untyped)
    version: int = 1
    placement: Placement = Placement.HOST
    stateful: bool = False               # wants a platform database attached
    min_instances: int = 1
    max_instances: int = 8
    pure_fn: Callable[..., Any] | None = None
    combinator: str = ""
    fused_stages: Sequence[str] = ()

    kind = EntityKind.ANALYTICS_UNIT


@dataclasses.dataclass(frozen=True)
class ActuatorSpec:
    """Controls a gadget using insights from input streams (paper §2)."""

    name: str
    logic: Callable[..., Any]
    config_schema: ConfigSchema = dataclasses.field(default_factory=ConfigSchema.empty)
    input_schemas: Sequence[StreamSchema] = ()
    version: int = 1

    kind = EntityKind.ACTUATOR


# ---------------------------------------------------------------------------
# Instance entities
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SensorSpec:
    """A registered physical/virtual data source, served by a driver.

    Registration (paper §4) requires (a) the driver installed, (b) the config
    valid under the driver's schema.  "A registered sensor always generates an
    output stream that has the same name as the sensor."
    """

    name: str
    driver: str
    config: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    #: Attach an append-only log to the sensor's output subject: every
    #: published reading is retained (subject to ``retention``) and late
    #: consumers can ``replay_from`` it.  Corpus/event sources set this so
    #: analytics added after the fact still see history.
    durable: bool = False
    #: Retention knobs for the durable log — a dict with any of
    #: ``max_records`` / ``max_age_s`` / ``max_bytes`` (None = unbounded).
    retention: Mapping[str, Any] | None = None

    kind = EntityKind.SENSOR


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """A derived (augmented) stream: AU + input streams + AU config (paper §4).

    ``delivery`` selects what scaled instances of this stream *are*:

    * ``"group"`` (default) — instances join one bus queue group per input
      subject; each message reaches exactly ONE of them (a worker pool —
      scaling N× adds N× capacity).  Other consumer streams and external
      subscribers are unaffected: broadcast across *different* groups is
      preserved, so §3 stream reuse still sees every message.
    * ``"keyed"`` — like ``"group"``, but the payload field named by ``key``
      is hashed onto a stable partition ring: every message for a key lands
      on the SAME instance (per-key order + per-key state locality), which
      is what lets *stateful* streams scale.  Requires ``key``; the field
      must exist in every typed input schema.
    * ``"broadcast"`` — every instance holds its own ungrouped subscription
      and receives every message (pre-queue-group replica semantics; the
      escape hatch for redundant/speculative execution).
    """

    name: str
    analytics_unit: str
    inputs: Sequence[str] = ()
    config: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    fixed_instances: int | None = None   # None => operator auto-scales
    delivery: str = "group"              # "group" | "keyed" | "broadcast"
    key: str | None = None               # hashed payload field (keyed only)
    #: Opt this stream's worker pool into pull-based work stealing (DSL
    #: ``.scaled(steal=True)``): an idle member pulls queued work from the
    #: deepest sibling mailbox.  Group stealing hands over individual
    #: messages (arrival order across the pool is perturbed — avoid when a
    #: downstream stage is order-sensitive); keyed stealing migrates whole
    #: partitions, preserving per-key order.  Meaningless for broadcast.
    steal: bool = False
    #: Burst ceiling for batched execution: when this stream's unit can batch
    #: (fused DEVICE chains expose ``process_batch``), each mailbox pull
    #: drains up to this many queued messages into ONE program call.  None
    #: defers to the unit's default; 1 forces per-message dispatch.  Set via
    #: the DSL's ``.scaled(max_batch=)``.
    max_batch: int | None = None
    #: Attach an append-only log to this stream's OUTPUT subject (DSL
    #: ``.durable(retention=...)``): downstream consumers may arrive late
    #: and replay, and the subject's history survives consumer churn.
    durable: bool = False
    #: Retention for the durable output log (dict of ``max_records`` /
    #: ``max_age_s`` / ``max_bytes``; None = unbounded).
    retention: Mapping[str, Any] | None = None
    #: Where this stream's instances START on their (durable) INPUT
    #: subjects: ``None`` = live only (fire-and-forget semantics), an int
    #: log offset, a float timestamp, ``"earliest"``, or ``"snapshot"`` —
    #: resolved by the operator against the stream's state database to the
    #: suffix after the last recovery watermark (exactly-once keyed
    #: recovery).  Requires every input subject to be durable.
    replay_from: Any = None

    kind = EntityKind.STREAM


@dataclasses.dataclass(frozen=True)
class GadgetSpec:
    """A controllable endpoint, driven by an actuator reading input streams."""

    name: str
    actuator: str
    inputs: Sequence[str] = ()
    config: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    kind = EntityKind.GADGET


@dataclasses.dataclass(frozen=True)
class DatabaseSpec:
    """A platform-managed database attachable to drivers/AUs/actuators (§2).

    'DataX installs and maintains the databases, while applications are
    responsible for the content' — schema here is the app-declared table set.
    """

    name: str
    engine: str = "memkv"                # memkv | sqlite-like file store
    tables: Mapping[str, Sequence[str]] = dataclasses.field(default_factory=dict)

    kind = EntityKind.DATABASE


CodeEntity = DriverSpec | AnalyticsUnitSpec | ActuatorSpec
InstanceEntity = SensorSpec | StreamSpec | GadgetSpec | DatabaseSpec
