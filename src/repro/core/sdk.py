"""DataX SDK — the developer-facing API (paper §4, "DataX SDKs").

The paper's Python SDK is a class ``DataX`` with exactly three public methods:

* ``get_configuration()`` — the entity's configuration as a dict
* ``next()``              — ``(stream_name, message_dict)`` from an input stream
* ``emit(message)``       — publish a dict on the output stream

plus (per §2) access to the platform database when the entity is stateful.
The SDK is a thin veneer over the Sidecar — "DataX Sidecar does most of the
work in managing data communication, and the SDKs provide an interface between
DataX Sidecar and the business logic".

Business logic can be written in two styles:

1. **SDK style** (the paper's): a long-running ``main(dx)`` decorated with
   :func:`sdk_entrypoint`, looping on ``dx.next()`` / ``dx.emit()``.
2. **Callback style**: a factory ``make(ctx) -> process`` where ``process``
   is called per message; the runtime owns the loop.  For drivers the factory
   may return an iterator, each item becoming one emitted message.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Iterator

from .sidecar import Sidecar
from .state import Database


class DataX:
    """The object handed to SDK-style business logic."""

    def __init__(self, sidecar: Sidecar, config: dict,
                 db: Database | None = None,
                 stop_event: threading.Event | None = None):
        self._sidecar = sidecar
        self._config = dict(config)
        self._db = db
        self._stop = stop_event or threading.Event()

    # -- the paper's three public methods ------------------------------------
    def get_configuration(self) -> dict:
        """Configuration as key-value pairs."""
        return dict(self._config)

    def next(self, timeout: float | None = 1.0) -> tuple[str, dict] | None:
        """(stream_name, message) from one of the input streams, or None."""
        item = self._sidecar.next(timeout=timeout)
        if item is None:
            return None
        stream, msg = item
        return (stream, msg.payload)

    def emit(self, message: dict) -> None:
        """Publish a new message (a dict with string keys) on the output."""
        if not isinstance(message, dict):
            raise TypeError("emit() takes a dict with string keys")
        self._sidecar.emit(message)

    # -- extras ---------------------------------------------------------------
    @property
    def db(self) -> Database | None:
        """The platform-managed database, if the entity is stateful (§2)."""
        return self._db

    @property
    def running(self) -> bool:
        """SDK-style mains should loop ``while dx.running:``."""
        return not self._stop.is_set()


class BatchInterrupted(RuntimeError):
    """A ``process_batch`` implementation failed partway through a burst.

    ``results`` is the successful prefix (per-message outputs, in order, up
    to but excluding the failing message).  Raised ``from`` the original
    exception.  The Executor's drain-a-burst pump emits the prefix and
    counts only the poison message and the unprocessed tail as lost —
    without this protocol a single poison message would destroy the whole
    popped burst, including fully-processed predecessors.
    """

    def __init__(self, results: list):
        super().__init__(f"batch interrupted after {len(results)} messages")
        self.results = results


def sdk_entrypoint(fn: Callable[[DataX], Any]) -> Callable[[DataX], Any]:
    """Mark a function as SDK-style business logic (owns its own loop)."""
    fn.datax_sdk_style = True  # type: ignore[attr-defined]
    return fn


def is_sdk_style(logic: Callable) -> bool:
    return bool(getattr(logic, "datax_sdk_style", False))


class LogicContext:
    """Context handed to callback-style factories."""

    def __init__(self, config: dict, db: Database | None = None,
                 instance_id: str = "", stop_event: threading.Event | None = None):
        self.config = dict(config)
        self.db = db
        self.instance_id = instance_id
        self._stop = stop_event or threading.Event()

    @property
    def running(self) -> bool:
        return not self._stop.is_set()


DriverIterator = Iterator[dict]
ProcessFn = Callable[[str, dict], Any]  # (stream, payload) -> payload | list | None
