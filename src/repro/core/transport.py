"""Cross-host bus transport — the platform leaves one process.

Everything the bus does in-process (queue groups, keyed rings, durable
replay) is membership logic over :class:`~.bus.Subscription` mailboxes; this
module puts a wire underneath it so a *second process* can join as a
first-class member.  Two halves:

* :class:`BusServer` — wraps a host's :class:`~.bus.MessageBus` and exposes
  its subjects over TCP.  Each remote subscription becomes a **proxy**: a
  normal local ``Subscription`` (so the queue group / keyed ring sees an
  ordinary member, with the peer-supplied stable name as its ring identity)
  plus a pump thread that ships popped messages to the peer as frames and
  tracks them **in flight until acknowledged**.  When a peer drops — socket
  error, clean ``bye``, or heartbeat silence — its unacknowledged frames are
  requeued at the front of the proxy mailbox and the proxy departs through
  the bus's normal atomic hand-off, so a crashed remote member re-homes its
  backlog to survivors exactly like a crashed thread does (per-key order
  preserved; a dropped connection is a *reaped member*, not a hang).

* :class:`RemoteBus` — the client half, satisfying the :class:`~.bus.BusLike`
  transport seam: ``subscribe(group=..., key=...)`` / ``publish`` /
  ``issue_token`` / metrics RPCs all speak frames to a ``BusServer``, so a
  :class:`~.sidecar.Sidecar` (and therefore a whole
  :class:`~.serverless.Executor` worker pool) runs against a remote host's
  bus unchanged.  Connection establishment retries with exponential backoff;
  liveness is heartbeat-based (client pings, server pongs, both sides reap
  silence); client-side counters (frames/bytes in/out, reconnects) surface
  through the sidecar's federated ``transport`` metrics.

**Wire format** (specified normatively in ``docs/wire-protocol.md``): every
frame is a 4-byte big-endian length followed by a codec-tagged compressed
blob (:mod:`~.compression` — zstd when available, zlib otherwise, readers
dispatch on the tag) containing one msgpack-encoded frame dict.  Message
payloads ride the existing numpy-aware encoding
(:func:`~.bus.encode_message`).

Delivery semantics across a peer crash are **at-least-once** at the frame
level (unacknowledged messages are redelivered to group survivors) and the
test/benchmark consumers make them exactly-once the same way the durable
layer does: acknowledge only after the message's effect is recorded.
"""
from __future__ import annotations

import itertools
import os
import socket
import struct
import sys
import threading
import time
from collections import deque
from typing import Iterable

import msgpack

from .bus import (KEYED_PARTITIONS, BusError, MessageBus, Subscription,
                  Unauthorized, UnknownSubject, _default, _ext_hook,
                  decode_message, encode_message, partition_of)
from .compression import compress, decompress
from .delivery import (DeliveryPolicy, ReplayFrom, policy_from_legacy,
                       resolve_policy, resolve_replay)
from .schema import Message

#: Protocol version carried in the handshake; a server refuses a client
#: whose major version differs (there is exactly one version today).
PROTO_VERSION = 1

#: Hard ceiling on one frame's blob size — a corrupted length prefix must
#: not make a reader allocate gigabytes.
MAX_FRAME_BYTES = 64 * 1024 * 1024

#: Default max unacknowledged messages per remote subscription (flow
#: control: the pump stops shipping until the peer acks).
DEFAULT_WINDOW = 256


class TransportError(BusError):
    """Connection-level failure (refused, dropped, timed out, bad frame)."""


_DEBUG = os.environ.get("DATAX_TRANSPORT_DEBUG", "") not in ("", "0")


def _dbg(*parts) -> None:
    """Connection-lifecycle tracing to stderr, enabled by
    ``DATAX_TRANSPORT_DEBUG=1`` (drops, reaps, reconnects — the events you
    need when a cross-process test misbehaves)."""
    if _DEBUG:
        print("[transport]", *parts, file=sys.stderr, flush=True)


_ERROR_KINDS = {
    "Unauthorized": Unauthorized,
    "UnknownSubject": UnknownSubject,
    "BusError": BusError,
    "TransportError": TransportError,
}


# ---------------------------------------------------------------------------
# Frames
# ---------------------------------------------------------------------------

def pack_frame(frame: dict, *, level: int = 1) -> bytes:
    """Encode one frame dict: msgpack (numpy-aware) → codec-tagged blob →
    4-byte big-endian length prefix."""
    blob = compress(msgpack.packb(frame, default=_default, use_bin_type=True),
                    level=level)
    if len(blob) > MAX_FRAME_BYTES:
        raise TransportError(f"frame too large ({len(blob)} bytes)")
    return struct.pack(">I", len(blob)) + blob


def unpack_frame(blob: bytes) -> dict:
    """Inverse of :func:`pack_frame` minus the length prefix (the reader
    strips it)."""
    return msgpack.unpackb(decompress(blob), ext_hook=_ext_hook, raw=False,
                           strict_map_key=False)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise ``ConnectionError`` on EOF."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(n - got)
        if not chunk:
            raise ConnectionError("peer closed the connection")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def read_frame(sock: socket.socket) -> tuple[dict, int]:
    """Read one length-prefixed frame; returns ``(frame, wire_bytes)``."""
    header = _recv_exact(sock, 4)
    (length,) = struct.unpack(">I", header)
    if length > MAX_FRAME_BYTES:
        raise TransportError(f"frame length {length} exceeds MAX_FRAME_BYTES")
    blob = _recv_exact(sock, length)
    return unpack_frame(blob), 4 + length


# ---------------------------------------------------------------------------
# Server side
# ---------------------------------------------------------------------------

class _ProxySub:
    """Server-side state for one remote subscription: the local proxy
    ``Subscription`` (the group/ring member), the in-flight window, and the
    pump thread shipping popped messages to the peer."""

    def __init__(self, sid: int, sub: Subscription, window: int,
                 key: str | None, n_partitions: int):
        self.sid = sid
        self.sub = sub
        self.window = max(1, window)
        self.key = key
        self.n_partitions = n_partitions
        self.inflight: deque[tuple[object, Message]] = deque()
        self.cond = threading.Condition()
        self.closed = threading.Event()
        self.thread: threading.Thread | None = None
        self.acked = 0

    def tag_of(self, msg: Message):
        if self.key is None:
            return None
        return partition_of(msg.payload.get(self.key), self.n_partitions)

    def ack(self, n: int) -> None:
        with self.cond:
            for _ in range(min(n, len(self.inflight))):
                self.inflight.popleft()
                self.acked += 1
            self.cond.notify_all()


class _Peer:
    """One connected client: socket, identity, counters, proxy registry."""

    def __init__(self, conn: socket.socket, addr):
        self.conn = conn
        self.addr = addr
        self.name = f"{addr[0]}:{addr[1]}"
        self.send_lock = threading.Lock()
        self.subs: dict[int, _ProxySub] = {}
        self.frames_in = 0
        self.frames_out = 0
        self.bytes_in = 0
        self.bytes_out = 0
        self.connected_at = time.monotonic()
        self.last_seen = self.connected_at
        self.dropped = False
        self.drop_lock = threading.Lock()


class BusServer:
    """Expose a host's :class:`~.bus.MessageBus` subjects over TCP.

    One listener thread accepts connections; each peer gets a reader thread
    (frame dispatch) and one pump thread per remote subscription.  A peer
    whose connection drops — or that stays silent past ``hb_timeout``
    seconds (clients ping every heartbeat interval) — is *reaped*: every
    unacknowledged in-flight message is requeued ahead of its proxy's
    backlog and the proxy departs through the bus's atomic group hand-off,
    re-homing the peer's share to surviving members.

    ``port=0`` binds an OS-assigned port; read :attr:`address` for the
    actual one.  The server is data-plane only — it never registers
    subjects itself; the Operator owning ``bus`` does (see
    :meth:`~.operator.Operator.serve`).
    """

    def __init__(self, bus: MessageBus, host: str = "127.0.0.1",
                 port: int = 0, *, window: int = DEFAULT_WINDOW,
                 hb_timeout: float = 10.0, compress_level: int = 1):
        self.bus = bus
        self.window = window
        self.hb_timeout = hb_timeout
        self._level = compress_level
        self._lock = threading.Lock()
        self._peers: dict[int, _Peer] = {}
        self._peer_ids = itertools.count()
        self._sids = itertools.count()
        self.accepted = 0
        self.reaped = 0          # peers dropped for heartbeat silence
        self.disconnects = 0     # peers gone for any reason
        self._closed = threading.Event()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(32)
        self.address: tuple[str, int] = self._listener.getsockname()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="busserver-accept", daemon=True)
        self._accept_thread.start()
        self._reaper_thread = threading.Thread(
            target=self._reap_loop, name="busserver-reaper", daemon=True)
        self._reaper_thread.start()

    # -- connection plumbing -------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                conn, addr = self._listener.accept()
            except OSError:
                return  # listener closed
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            peer = _Peer(conn, addr)
            pid = next(self._peer_ids)
            with self._lock:
                self._peers[pid] = peer
                self.accepted += 1
            threading.Thread(target=self._serve_peer, args=(pid, peer),
                             name=f"busserver-peer-{pid}", daemon=True).start()

    def _serve_peer(self, pid: int, peer: _Peer) -> None:
        try:
            while not self._closed.is_set():
                frame, nbytes = read_frame(peer.conn)
                peer.frames_in += 1
                peer.bytes_in += nbytes
                peer.last_seen = time.monotonic()
                if not self._dispatch(peer, frame):
                    break  # clean bye
        except (ConnectionError, OSError, TransportError,
                msgpack.UnpackException) as e:
            _dbg(f"server: peer {peer.name} read loop ended: {e!r}")
        finally:
            self._drop_peer(pid, peer)

    def _send(self, peer: _Peer, frame: dict) -> None:
        data = pack_frame(frame, level=self._level)
        with peer.send_lock:
            peer.conn.sendall(data)
            peer.frames_out += 1
            peer.bytes_out += len(data)

    def _reply(self, peer: _Peer, rid, **kw) -> None:
        self._send(peer, {"rid": rid, "ok": True, **kw})

    def _reply_error(self, peer: _Peer, rid, exc: Exception) -> None:
        kind = type(exc).__name__
        if kind not in _ERROR_KINDS:
            kind = "BusError"
        self._send(peer, {"rid": rid, "ok": False, "kind": kind,
                          "error": str(exc)})

    # -- frame dispatch ------------------------------------------------------
    def _dispatch(self, peer: _Peer, frame: dict) -> bool:
        """Handle one frame; returns False on a clean ``bye``."""
        op = frame.get("op")
        rid = frame.get("rid")
        if op == "ping":
            self._send(peer, {"op": "pong", "t": frame.get("t")})
            return True
        if op == "ack":
            proxy = peer.subs.get(frame["sid"])
            if proxy is not None:
                proxy.ack(int(frame.get("n", 1)))
            return True
        if op == "bye":
            return False
        try:
            if op == "hello":
                if int(frame.get("proto", 0)) != PROTO_VERSION:
                    raise TransportError(
                        f"protocol version mismatch: server speaks "
                        f"{PROTO_VERSION}, client {frame.get('proto')}")
                if frame.get("peer"):
                    peer.name = str(frame["peer"])
                self._reply(peer, rid, proto=PROTO_VERSION,
                            subjects=self.bus.subjects())
            elif op == "issue_token":
                token = self.bus.issue_token(frame.get("name", peer.name),
                                             frame.get("subjects"))
                self._reply(peer, rid, token=token)
            elif op == "revoke_token":
                self.bus.revoke_token(frame["token"])
                self._reply(peer, rid)
            elif op == "subscribe":
                self._handle_subscribe(peer, rid, frame)
            elif op == "unsubscribe":
                self._retire_proxy(peer, frame["sid"], clean=True)
                self._reply(peer, rid)
            elif op == "publish":
                msg = self.bus.publish(frame["subject"], frame["payload"],
                                       token=frame["token"],
                                       headers=frame.get("headers"))
                self._reply(peer, rid, seq=msg.seq,
                            offset=msg.headers.get("offset"))
            elif op == "stats":
                self._reply(peer, rid, stats=self.bus.stats())
            elif op == "group_info":
                self._reply(peer, rid, info=self.bus.group_info(
                    frame["subject"], frame["group"]))
            elif op == "durable_info":
                log = self.bus.durable_log(frame["subject"])
                self._reply(peer, rid,
                            info=None if log is None else log.info())
            elif op == "backlog":
                self._reply(peer, rid, backlog=self.bus.backlog(
                    frame["subject"]))
            elif op == "subjects":
                self._reply(peer, rid, subjects=self.bus.subjects())
            elif op == "note_lost":
                self.bus.note_lost(frame["subject"], int(frame.get("n", 1)))
                if rid is not None:
                    self._reply(peer, rid)
            else:
                raise TransportError(f"unknown op {op!r}")
        except Exception as e:  # surface bus errors to the caller, not the log
            if rid is not None:
                self._reply_error(peer, rid, e)
        return True

    def _handle_subscribe(self, peer: _Peer, rid, frame: dict) -> None:
        key = frame.get("key")
        partitions = int(frame.get("partitions") or KEYED_PARTITIONS)
        replay_from = frame.get("replay_from")
        sub = self.bus.subscribe(
            frame["subject"], token=frame["token"],
            maxsize=frame.get("maxsize"), wire=False,
            name=frame.get("name") or f"{peer.name}#{frame.get('sid', '?')}",
            policy=policy_from_legacy(frame.get("group"), key, partitions),
            replay=ReplayFrom(replay_from) if replay_from is not None
            else None)
        sid = int(frame["sid"])
        proxy = _ProxySub(sid, sub, min(self.window,
                                        frame.get("maxsize") or self.window),
                          key, partitions)
        peer.subs[sid] = proxy
        proxy.thread = threading.Thread(
            target=self._pump, args=(peer, proxy),
            name=f"busserver-pump-{peer.name}-{sid}", daemon=True)
        proxy.thread.start()
        self._reply(peer, rid, sid=sid)

    # -- the pump: proxy mailbox -> wire, with an acked window ---------------
    def _pump(self, peer: _Peer, proxy: _ProxySub) -> None:
        sub = proxy.sub
        while not proxy.closed.is_set():
            with proxy.cond:
                while (len(proxy.inflight) >= proxy.window
                       and not proxy.closed.is_set()):
                    proxy.cond.wait(0.25)
                budget = proxy.window - len(proxy.inflight)
            if proxy.closed.is_set():
                return
            msgs = sub.next_batch(max(1, min(budget, 64)), timeout=0.25)
            if not msgs:
                if sub.closed and sub.qsize() == 0:
                    # subject unregistered / bus closed underneath us — tell
                    # the client so its consumer unblocks instead of hanging
                    try:
                        self._send(peer, {"op": "sub_closed",
                                          "sid": proxy.sid})
                    except OSError:
                        pass
                    return
                continue
            # in-flight BEFORE send: if the send fails the messages are
            # still tracked and will be requeued by the drop path
            with proxy.cond:
                for m in msgs:
                    proxy.inflight.append((proxy.tag_of(m), m))
            try:
                for m in msgs:
                    self._send(peer, {"op": "msg", "sid": proxy.sid,
                                      "m": encode_message(m)})
            except OSError as e:
                # reader thread sees the dead socket too and runs the drop
                # path; just stop pumping
                _dbg(f"server: pump {peer.name}#{proxy.sid} send failed: {e!r}")
                return

    def _retire_proxy(self, peer: _Peer, sid: int, *, clean: bool) -> None:
        """Stop a proxy's pump, requeue its unacknowledged messages ahead of
        the backlog, and depart the bus — the single redelivery path for
        clean unsubscribes, clean byes, and crashed peers alike."""
        proxy = peer.subs.pop(sid, None)
        if proxy is None:
            return
        proxy.closed.set()
        with proxy.cond:
            proxy.cond.notify_all()
        if proxy.thread is not None and proxy.thread is not \
                threading.current_thread():
            proxy.thread.join(timeout=2.0)
        with proxy.cond:
            pending = list(proxy.inflight)
            proxy.inflight.clear()
        proxy.sub.requeue_front(pending)
        self.bus.unsubscribe(proxy.sub)

    def _drop_peer(self, pid: int, peer: _Peer) -> None:
        with peer.drop_lock:
            if peer.dropped:
                return
            peer.dropped = True
        with self._lock:
            self._peers.pop(pid, None)
            self.disconnects += 1
        for sid in list(peer.subs):
            self._retire_proxy(peer, sid, clean=False)
        try:
            peer.conn.close()
        except OSError:
            pass

    def _reap_loop(self) -> None:
        while not self._closed.wait(min(1.0, self.hb_timeout / 4)):
            now = time.monotonic()
            with self._lock:
                stale = [(pid, p) for pid, p in self._peers.items()
                         if now - p.last_seen > self.hb_timeout]
            for pid, peer in stale:
                self.reaped += 1
                _dbg(f"server: reaping {peer.name} "
                     f"(silent {now - peer.last_seen:.1f}s)")
                try:
                    peer.conn.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
                self._drop_peer(pid, peer)

    # -- introspection / lifecycle -------------------------------------------
    def stats(self) -> dict:
        """Federated transport view: per-peer connection state, frame/byte
        counters, subscription + in-flight depth — the server half of the
        ``transport`` metrics surface (see ``docs/metrics.md``)."""
        now = time.monotonic()
        with self._lock:
            peers = list(self._peers.values())
        return {
            "address": list(self.address),
            "peers": {
                p.name: {
                    "addr": f"{p.addr[0]}:{p.addr[1]}",
                    "connected_s": now - p.connected_at,
                    "last_seen_s": now - p.last_seen,
                    "frames_in": p.frames_in,
                    "frames_out": p.frames_out,
                    "bytes_in": p.bytes_in,
                    "bytes_out": p.bytes_out,
                    "subscriptions": len(p.subs),
                    "inflight": sum(len(s.inflight) for s in p.subs.values()),
                }
                for p in peers
            },
            "accepted": self.accepted,
            "reaped": self.reaped,
            "disconnects": self.disconnects,
        }

    def close(self) -> None:
        """Stop accepting, drop every peer (reaping their proxies)."""
        self._closed.set()
        try:
            self._listener.close()
        except OSError:
            pass
        with self._lock:
            peers = list(self._peers.items())
        for pid, peer in peers:
            try:
                peer.conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            self._drop_peer(pid, peer)


# ---------------------------------------------------------------------------
# Client side
# ---------------------------------------------------------------------------

class RemoteSubscription:
    """Client half of a remote subscription — the :class:`~.bus.Subscription`
    surface the sidecar reads, backed by frames from the server's proxy.

    Messages are **acknowledged when popped** (``auto_ack=True``, the
    default — right for the executor's pump, which owns redelivery through
    the reconciler) or explicitly via :meth:`ack` (``auto_ack=False`` —
    consumers that must survive their own crash ack only after recording a
    message's effect, which is what makes redelivery exactly-once
    end-to-end).  Replay state (``replaying`` etc.) lives server-side on the
    proxy; the client-side counters exist for metrics compatibility.
    """

    def __init__(self, bus: "RemoteBus", sid: int, subject: str, name: str,
                 group: str | None, auto_ack: bool):
        self._bus = bus
        self.sid = sid
        self.subject = subject
        self.name = name
        self.group = group
        self.wire = False
        self.auto_ack = auto_ack
        self.received = 0
        self.dropped = 0
        self.closed = False
        self.replayed = 0
        self.deduped = 0
        self.healed = 0
        self._q: deque[Message] = deque()
        self._cond = threading.Condition()

    @property
    def replaying(self) -> bool:
        """Always False client-side: the server proxy drains replay before
        any frame is shipped, so by the time a message arrives here the
        replay→live ordering is already settled."""
        return False

    def replay_lag(self) -> int:
        """Client-side stub (0); use the ``durable_info`` RPC for the log
        view."""
        return 0

    def _deliver(self, msg: Message) -> None:
        with self._cond:
            self._q.append(msg)
            self.received += 1
            self._cond.notify()

    def _close_local(self) -> None:
        with self._cond:
            self.closed = True
            self._cond.notify_all()

    def next(self, timeout: float | None = None) -> Message | None:
        """Blocking pop; None on timeout or close."""
        got = self.next_batch(1, timeout)
        return got[0] if got else None

    def next_batch(self, max_n: int,
                   timeout: float | None = None) -> list[Message]:
        """Pop up to ``max_n`` received messages (blocking up to ``timeout``
        for the first, like :meth:`.bus.Subscription.next_batch`); with
        ``auto_ack`` the pop acknowledges them to the server."""
        if max_n < 1:
            return []
        out: list[Message] = []
        with self._cond:
            if not self._q and not self.closed:
                self._cond.wait(timeout)
            while self._q and len(out) < max_n:
                out.append(self._q.popleft())
        if out and self.auto_ack:
            self._bus._ack(self.sid, len(out))
        return out

    def ack(self, n: int = 1) -> None:
        """Acknowledge ``n`` popped messages (``auto_ack=False`` mode).
        Unacknowledged messages are redelivered to group survivors if this
        client drops."""
        self._bus._ack(self.sid, n)

    def qsize(self) -> int:
        """Messages received but not yet popped."""
        with self._cond:
            return len(self._q)

    def close(self) -> None:
        """Local close; prefer ``RemoteBus.unsubscribe`` for a clean leave."""
        self._close_local()


class _RemoteLogHandle:
    """Client-side handle to a remote subject's durable log: just enough of
    the :class:`~.durable.DurableLog` surface for metrics (``info()``)."""

    def __init__(self, bus: "RemoteBus", subject: str):
        self._bus = bus
        self.subject = subject

    def info(self) -> dict:
        """The remote log's catalog entry (RPC per call)."""
        info = self._bus._rpc("durable_info", subject=self.subject)["info"]
        return info or {}


class RemoteBus:
    """TCP client satisfying the :class:`~.bus.BusLike` seam against a
    remote :class:`BusServer`.

    ``address`` is ``"host:port"`` or a ``(host, port)`` tuple.  The
    constructor connects eagerly, retrying with exponential backoff until
    ``connect_timeout`` elapses — so a worker process can be started before
    its server and still come up.  A heartbeat thread pings every
    ``hb_interval`` seconds; if nothing (pong or data) arrives within
    ``hb_timeout`` the connection is declared dead: pending RPCs fail,
    every subscription closes (consumers unblock — the server reaps the
    member and re-homes its share), and the next RPC attempts a fresh
    connection (counted in ``reconnects``).  Subscriptions do NOT silently
    re-subscribe across a reconnect: membership is explicit, a new
    subscription is a new ring identity.
    """

    def __init__(self, address, *, peer: str = "",
                 connect_timeout: float = 5.0, rpc_timeout: float = 10.0,
                 hb_interval: float = 1.0, hb_timeout: float = 6.0,
                 compress_level: int = 1):
        if isinstance(address, str):
            host, _, port = address.rpartition(":")
            address = (host or "127.0.0.1", int(port))
        self.address: tuple[str, int] = tuple(address)
        self.peer = peer or f"remote-{id(self):x}"
        self._connect_timeout = connect_timeout
        self._rpc_timeout = rpc_timeout
        self._hb_interval = hb_interval
        self._hb_timeout = hb_timeout
        self._level = compress_level
        self._lock = threading.RLock()       # connection state
        self._send_lock = threading.Lock()
        self._sock: socket.socket | None = None
        self._rids = itertools.count()
        self._sids = itertools.count()
        self._waiters: dict[int, tuple[threading.Event, list]] = {}
        self._subs: dict[int, RemoteSubscription] = {}
        self._closed = False
        self._last_frame = 0.0
        # federated metrics (the client half of docs/metrics.md "transport")
        self.frames_in = 0
        self.frames_out = 0
        self.bytes_in = 0
        self.bytes_out = 0
        self.reconnects = 0
        self.subjects_cache: list[str] = []
        self._connect(initial=True)
        self._hb_thread = threading.Thread(
            target=self._hb_loop, name=f"remotebus-hb-{self.peer}",
            daemon=True)
        self._hb_thread.start()

    # -- connection management ----------------------------------------------
    def connected(self) -> bool:
        """True while a live socket exists."""
        with self._lock:
            return self._sock is not None and not self._closed

    def _connect(self, *, initial: bool = False) -> None:
        """(Re)establish the connection, with exponential backoff up to
        ``connect_timeout`` total."""
        deadline = time.monotonic() + self._connect_timeout
        backoff = 0.05
        last_err: Exception | None = None
        while time.monotonic() < deadline and not self._closed:
            try:
                sock = socket.create_connection(
                    self.address, timeout=max(0.2, deadline - time.monotonic()))
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                sock.settimeout(None)
                with self._lock:
                    self._sock = sock
                    if not initial:
                        self.reconnects += 1
                    self._last_frame = time.monotonic()
                threading.Thread(target=self._read_loop, args=(sock,),
                                 name=f"remotebus-read-{self.peer}",
                                 daemon=True).start()
                hello = self._rpc("hello", peer=self.peer,
                                  proto=PROTO_VERSION)
                self.subjects_cache = list(hello.get("subjects", []))
                return
            except (OSError, TransportError) as e:
                last_err = e
                with self._lock:
                    self._sock = None
                time.sleep(backoff)
                backoff = min(backoff * 2, 1.0)
        raise TransportError(
            f"could not connect to bus server at "
            f"{self.address[0]}:{self.address[1]} within "
            f"{self._connect_timeout}s: {last_err}")

    def _drop_connection(self, reason: str) -> None:
        _dbg(f"client {self.peer}: dropping connection: {reason}")
        with self._lock:
            sock, self._sock = self._sock, None
            waiters = list(self._waiters.values())
            self._waiters.clear()
            subs = list(self._subs.values())
            self._subs.clear()
        if sock is not None:
            # shutdown() before close(): the reader thread still holds the
            # fd, so a bare close() would neither send FIN to the server nor
            # unblock the local recv — the peer would linger until reaped
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass
        for event, slot in waiters:
            slot.append(TransportError(f"connection lost: {reason}"))
            event.set()
        for sub in subs:
            sub._close_local()

    def _read_loop(self, sock: socket.socket) -> None:
        try:
            while True:
                frame, nbytes = read_frame(sock)
                with self._lock:
                    if self._sock is not sock:
                        return  # superseded by a reconnect
                    self.frames_in += 1
                    self.bytes_in += nbytes
                    self._last_frame = time.monotonic()
                self._handle_frame(frame)
        except (ConnectionError, OSError, TransportError,
                msgpack.UnpackException) as e:
            with self._lock:
                current = self._sock is sock
            if current:
                self._drop_connection(repr(e))

    def _handle_frame(self, frame: dict) -> None:
        rid = frame.get("rid")
        if rid is not None:
            with self._lock:
                waiter = self._waiters.pop(rid, None)
            if waiter is not None:
                event, slot = waiter
                slot.append(frame)
                event.set()
            return
        op = frame.get("op")
        if op == "msg":
            sub = self._subs.get(frame["sid"])
            if sub is not None:
                sub._deliver(decode_message(frame["m"]))
            else:
                # arrived after a local unsubscribe raced the pump — the
                # server redelivers it when the unsubscribe lands
                pass
        elif op == "sub_closed":
            sub = self._subs.pop(frame["sid"], None)
            if sub is not None:
                sub._close_local()
        # pongs need no handling beyond the last_frame stamp above

    def _hb_loop(self) -> None:
        while not self._closed:
            time.sleep(self._hb_interval)
            if self._closed:
                return
            with self._lock:
                sock = self._sock
                stale = (sock is not None and
                         time.monotonic() - self._last_frame
                         > self._hb_timeout)
            if sock is None:
                continue
            if stale:
                self._drop_connection("heartbeat timeout")
                continue
            try:
                self._send_frame({"op": "ping", "t": time.monotonic()})
            except TransportError:
                pass  # _send_frame already dropped the connection

    # -- frame / rpc plumbing -------------------------------------------------
    def _send_frame(self, frame: dict) -> None:
        data = pack_frame(frame, level=self._level)
        with self._lock:
            sock = self._sock
        if sock is None:
            raise TransportError("not connected")
        try:
            with self._send_lock:
                sock.sendall(data)
        except OSError as e:
            self._drop_connection(repr(e))
            raise TransportError(f"send failed: {e}") from None
        with self._lock:
            self.frames_out += 1
            self.bytes_out += len(data)

    def _rpc(self, op: str, *, _timeout: float | None = None, **kw) -> dict:
        """Send a request frame and wait for its correlated reply; maps
        server-side bus errors back to their exception types.  Attempts one
        reconnect (with backoff) when the connection is down."""
        if self._closed:
            raise TransportError("RemoteBus is closed")
        if not self.connected() and op != "hello":
            self._connect()
        rid = next(self._rids)
        event, slot = threading.Event(), []
        with self._lock:
            self._waiters[rid] = (event, slot)
        try:
            self._send_frame({"op": op, "rid": rid, **kw})
        except TransportError:
            with self._lock:
                self._waiters.pop(rid, None)
            raise
        if not event.wait(_timeout or self._rpc_timeout):
            with self._lock:
                self._waiters.pop(rid, None)
            raise TransportError(f"rpc {op!r} timed out")
        reply = slot[0]
        if isinstance(reply, Exception):
            raise reply
        if not reply.get("ok", False):
            exc = _ERROR_KINDS.get(reply.get("kind", ""), BusError)
            raise exc(reply.get("error", "remote error"))
        return reply

    def _ack(self, sid: int, n: int) -> None:
        try:
            self._send_frame({"op": "ack", "sid": sid, "n": n})
        except TransportError:
            pass  # the server redelivers unacked messages to survivors

    # -- the BusLike surface ---------------------------------------------------
    def issue_token(self, name: str,
                    subjects: Iterable[str] | None = None) -> str:
        """Mint a token on the remote bus (None = allowed everywhere)."""
        return self._rpc("issue_token", name=name,
                         subjects=None if subjects is None
                         else list(subjects))["token"]

    def revoke_token(self, token: str) -> None:
        """Invalidate a remote token (best-effort when disconnected)."""
        try:
            self._rpc("revoke_token", token=token)
        except TransportError:
            pass

    def subscribe(self, subject: str, *, token: str,
                  maxsize: int | None = None, wire: bool = False,
                  name: str = "", policy: DeliveryPolicy | None = None,
                  replay: ReplayFrom | None = None,
                  group: str | None = None,
                  key: str | None = None,
                  partitions: int | None = None,
                  replay_from=None, auto_ack: bool = True
                  ) -> RemoteSubscription:
        """Join the remote subject — as a first-class queue-group or
        keyed-ring member under a :class:`~.delivery.Group` /
        :class:`~.delivery.Keyed` ``policy`` (``name`` is the ring identity;
        pick a stable one for keyed recovery).  The deprecated
        ``group=``/``key=``/``partitions=``/``replay_from=`` kwargs map onto
        ``policy``/``replay`` with a warning, exactly as on
        :meth:`MessageBus.subscribe`.  ``wire`` is accepted for signature
        compatibility and ignored: everything here crosses the wire by
        construction.  ``auto_ack=False`` defers acknowledgement to
        :meth:`RemoteSubscription.ack` for exactly-once consumers."""
        group, key, partitions = resolve_policy(policy, group, key,
                                                partitions)
        replay_from = resolve_replay(replay, replay_from)
        del wire  # every remote delivery is wire-encoded already
        sid = next(self._sids)
        sub = RemoteSubscription(self, sid, subject,
                                 name or f"{self.peer}#{sid}", group,
                                 auto_ack)
        with self._lock:
            self._subs[sid] = sub
        try:
            self._rpc("subscribe", sid=sid, subject=subject, token=token,
                      maxsize=maxsize, name=sub.name, group=group, key=key,
                      partitions=partitions, replay_from=replay_from)
        except Exception:
            with self._lock:
                self._subs.pop(sid, None)
            raise
        return sub

    def unsubscribe(self, sub: RemoteSubscription) -> None:
        """Clean leave: the server requeues anything unacknowledged and
        departs the proxy (group backlog re-homes to survivors)."""
        with self._lock:
            self._subs.pop(sub.sid, None)
        try:
            self._rpc("unsubscribe", sid=sub.sid)
        except TransportError:
            pass  # connection already gone — the server reaped the proxy
        sub._close_local()

    def publish(self, subject: str, payload: dict, *, token: str,
                headers: dict | None = None) -> Message:
        """Publish through the server's bus (authz + schema validation and
        durable append happen there); returns the delivered message's
        envelope with its remote ``seq`` (and ``offset`` when durable)."""
        reply = self._rpc("publish", subject=subject, payload=payload,
                          token=token, headers=headers)
        hdrs = dict(headers or {})
        if reply.get("offset") is not None:
            hdrs["offset"] = reply["offset"]
        return Message(subject=subject, payload=payload, seq=reply["seq"],
                       headers=hdrs)

    def note_lost(self, subject: str, n: int = 1) -> None:
        """Forward poison-message loss accounting to the remote subject."""
        try:
            self._send_frame({"op": "note_lost", "subject": subject, "n": n})
        except TransportError:
            pass

    def group_info(self, subject: str, group: str) -> dict | None:
        """Snapshot of a remote queue group (RPC)."""
        return self._rpc("group_info", subject=subject, group=group)["info"]

    def durable_log(self, subject: str):
        """A metrics handle to the remote subject's durable log, or None
        for fire-and-forget subjects."""
        info = self._rpc("durable_info", subject=subject)["info"]
        return None if info is None else _RemoteLogHandle(self, subject)

    def stats(self) -> dict:
        """The remote bus's full per-subject stats (RPC)."""
        return self._rpc("stats")["stats"]

    def backlog(self, subject: str) -> int:
        """Deepest consumer lag on the remote subject (RPC)."""
        return self._rpc("backlog", subject=subject)["backlog"]

    def subjects(self) -> list[str]:
        """Registered subjects on the remote bus (RPC; also cached from the
        handshake in ``subjects_cache``)."""
        subjects = self._rpc("subjects")["subjects"]
        self.subjects_cache = list(subjects)
        return subjects

    # -- federated metrics -----------------------------------------------------
    def transport_stats(self) -> dict:
        """Client-side connection state + frame counters; the sidecar
        surfaces this under its ``transport`` metric (docs/metrics.md)."""
        with self._lock:
            return {
                "peer": f"{self.address[0]}:{self.address[1]}",
                "connected": self._sock is not None and not self._closed,
                "frames_in": self.frames_in,
                "frames_out": self.frames_out,
                "bytes_in": self.bytes_in,
                "bytes_out": self.bytes_out,
                "reconnects": self.reconnects,
                "subscriptions": len(self._subs),
            }

    def close(self) -> None:
        """Clean shutdown: unsubscribe everything, say bye, drop the
        socket."""
        if self._closed:
            return
        for sub in list(self._subs.values()):
            self.unsubscribe(sub)
        try:
            self._send_frame({"op": "bye"})
        except TransportError:
            pass
        self._closed = True
        self._drop_connection("closed")


__all__ = [
    "PROTO_VERSION", "MAX_FRAME_BYTES", "DEFAULT_WINDOW",
    "BusServer", "RemoteBus", "RemoteSubscription", "TransportError",
    "pack_frame", "read_frame", "unpack_frame",
]
