"""Cross-host bus transport — the platform leaves one process.

Everything the bus does in-process (queue groups, keyed rings, durable
replay) is membership logic over :class:`~.bus.Subscription` mailboxes; this
module puts a wire underneath it so a *second process* can join as a
first-class member.  Two halves:

* :class:`BusServer` — wraps a host's :class:`~.bus.MessageBus` and exposes
  its subjects over TCP.  Each remote subscription becomes a **proxy**: a
  normal local ``Subscription`` (so the queue group / keyed ring sees an
  ordinary member, with the peer-supplied stable name as its ring identity)
  plus a pump thread that ships popped messages to the peer as frames and
  tracks them **in flight until acknowledged**.  When a peer drops — socket
  error, clean ``bye``, or heartbeat silence — its unacknowledged frames are
  requeued at the front of the proxy mailbox and the proxy departs through
  the bus's normal atomic hand-off, so a crashed remote member re-homes its
  backlog to survivors exactly like a crashed thread does (per-key order
  preserved; a dropped connection is a *reaped member*, not a hang).

* :class:`RemoteBus` — the client half, satisfying the :class:`~.bus.BusLike`
  transport seam: ``subscribe(group=..., key=...)`` / ``publish`` /
  ``issue_token`` / metrics RPCs all speak frames to a ``BusServer``, so a
  :class:`~.sidecar.Sidecar` (and therefore a whole
  :class:`~.serverless.Executor` worker pool) runs against a remote host's
  bus unchanged.  Connection establishment retries with exponential backoff;
  liveness is heartbeat-based (client pings, server pongs, both sides reap
  silence); client-side counters (frames/bytes in/out, reconnects) surface
  through the sidecar's federated ``transport`` metrics.

**Wire format** (specified normatively in ``docs/wire-protocol.md``): every
frame is a 4-byte big-endian length followed by a codec-tagged compressed
blob (:mod:`~.compression` — zstd when available, zlib otherwise, readers
dispatch on the tag) containing one msgpack-encoded frame dict.  Message
payloads ride the existing numpy-aware encoding
(:func:`~.bus.encode_message`).

Delivery semantics across a peer crash are **at-least-once** at the frame
level (unacknowledged messages are redelivered to group survivors) and the
test/benchmark consumers make them exactly-once the same way the durable
layer does: acknowledge only after the message's effect is recorded.
"""
from __future__ import annotations

import contextlib
import itertools
import os
import socket
import struct
import sys
import threading
import time
from collections import deque
from typing import Iterable

import msgpack

import dataclasses

from .bus import (KEYED_PARTITIONS, BusError, MessageBus, Subscription,
                  Unauthorized, UnknownSubject, _default, _ext_hook,
                  decode_message, encode_message, partition_of)
from .compression import (available_codecs, compress, decompress,
                          train_dictionary)
from .delivery import (DeliveryPolicy, ReplayFrom, policy_from_legacy,
                       resolve_policy, resolve_replay)
from .schema import Message

#: Protocol version carried in the handshake.  v2 adds the negotiated fast
#: path: codec agreement (a zlib-only peer talks to a zstd host by
#: negotiating down), coalesced ``msgs`` delivery frames, batched ``pubs``,
#: and per-connection trained-dictionary compression.  The server still
#: accepts v1 hellos — and peers that never say hello at all get v1 framing
#: (one ``msg`` per frame, host-default codec), so old clients keep working.
PROTO_VERSION = 2

#: Protocol versions the server will accept in a hello.
SUPPORTED_PROTOS = (1, 2)

#: Hard ceiling on one frame's blob size — a corrupted length prefix must
#: not make a reader allocate gigabytes.
MAX_FRAME_BYTES = 64 * 1024 * 1024

#: Default max unacknowledged messages per remote subscription (flow
#: control: the pump stops shipping until the peer acks).
DEFAULT_WINDOW = 256

#: Default ceiling on messages coalesced into one ``msgs`` frame (v2).  The
#: hello negotiates ``min(server, client)`` per connection.
DEFAULT_MAX_FRAME_MSGS = 64

#: Soft cap on one coalesced frame's *serialized* payload bytes — a frame
#: flushes when adding the next message would cross it, so huge payloads
#: don't snowball into multi-megabyte frames that stall the pipe.
MAX_COALESCED_BYTES = 512 * 1024

#: Frames sampled per connection direction before a zstd dictionary is
#: trained (``compression.train_dictionary``, the durable-segment training
#: path) and announced to the receiver via a ``dict`` frame.
DICT_TRAIN_FRAMES = 32


class TransportError(BusError):
    """Connection-level failure (refused, dropped, timed out, bad frame)."""


_DEBUG = os.environ.get("DATAX_TRANSPORT_DEBUG", "") not in ("", "0")


def _dbg(*parts) -> None:
    """Connection-lifecycle tracing to stderr, enabled by
    ``DATAX_TRANSPORT_DEBUG=1`` (drops, reaps, reconnects — the events you
    need when a cross-process test misbehaves)."""
    if _DEBUG:
        print("[transport]", *parts, file=sys.stderr, flush=True)


_ERROR_KINDS = {
    "Unauthorized": Unauthorized,
    "UnknownSubject": UnknownSubject,
    "BusError": BusError,
    "TransportError": TransportError,
}


# ---------------------------------------------------------------------------
# Frames
# ---------------------------------------------------------------------------

def _encode_frame(frame: dict, *, level: int = 1, codec: str | None = None,
                  dictionary: bytes | None = None) -> tuple[bytes, bytes]:
    """Encode one frame dict; returns ``(wire_data, raw_msgpack)``.

    ``wire_data`` is the length-prefixed codec-tagged blob that goes on the
    socket; ``raw_msgpack`` is the pre-compression serialization — callers
    use its length for the ``wire_ratio`` metric and its bytes as dictionary
    training samples.  ``codec`` pins the negotiated wire codec (None =
    host default, the v1 behaviour); ``dictionary`` switches zstd to
    dictionary compression (tag ``DXZ2`` — only legal after the dictionary
    was announced to the receiver)."""
    raw = msgpack.packb(frame, default=_default, use_bin_type=True)
    blob = compress(raw, level=level, codec=codec, dictionary=dictionary)
    if len(blob) > MAX_FRAME_BYTES:
        raise TransportError(f"frame too large ({len(blob)} bytes)")
    return struct.pack(">I", len(blob)) + blob, raw


def pack_frame(frame: dict, *, level: int = 1, codec: str | None = None,
               dictionary: bytes | None = None) -> bytes:
    """Encode one frame dict: msgpack (numpy-aware) → codec-tagged blob →
    4-byte big-endian length prefix.  ``codec``/``dictionary`` select the
    negotiated wire codec (see :func:`_encode_frame`)."""
    data, _ = _encode_frame(frame, level=level, codec=codec,
                            dictionary=dictionary)
    return data


def unpack_frame(blob: bytes, *, dictionary: bytes | None = None) -> dict:
    """Inverse of :func:`pack_frame` minus the length prefix (the reader
    strips it).  ``dictionary`` is required to read ``DXZ2`` blobs — the
    receive-side copy of the connection's announced dictionary."""
    return msgpack.unpackb(decompress(blob, dictionary=dictionary),
                           ext_hook=_ext_hook, raw=False,
                           strict_map_key=False)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise ``ConnectionError`` on EOF."""
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(n - got)
        if not chunk:
            raise ConnectionError("peer closed the connection")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def read_frame(sock: socket.socket, *,
               dictionary=None) -> tuple[dict, int, int]:
    """Read one length-prefixed frame; returns ``(frame, wire_bytes,
    raw_bytes)`` — wire bytes as received (prefix included) and the
    decompressed serialization size, the pair the compression-ratio metric
    is built from.  ``dictionary`` may be bytes or a zero-arg callable
    returning the current receive dictionary (a ``dict`` announcement can
    land mid-stream, so readers resolve it per frame)."""
    header = _recv_exact(sock, 4)
    (length,) = struct.unpack(">I", header)
    if length > MAX_FRAME_BYTES:
        raise TransportError(f"frame length {length} exceeds MAX_FRAME_BYTES")
    blob = _recv_exact(sock, length)
    d = dictionary() if callable(dictionary) else dictionary
    raw = decompress(blob, dictionary=d)
    frame = msgpack.unpackb(raw, ext_hook=_ext_hook, raw=False,
                            strict_map_key=False)
    return frame, 4 + length, len(raw)


# ---------------------------------------------------------------------------
# Server side
# ---------------------------------------------------------------------------

class _ProxySub:
    """Server-side state for one remote subscription: the local proxy
    ``Subscription`` (the group/ring member), the in-flight window, and the
    pump thread shipping popped messages to the peer."""

    def __init__(self, sid: int, sub: Subscription, window: int,
                 key: str | None, n_partitions: int):
        self.sid = sid
        self.sub = sub
        self.window = max(1, window)
        self.key = key
        self.n_partitions = n_partitions
        self.inflight: deque[tuple[object, Message]] = deque()
        self.cond = threading.Condition()
        self.closed = threading.Event()
        self.thread: threading.Thread | None = None
        self.acked = 0

    def tag_of(self, msg: Message):
        if self.key is None:
            return None
        return partition_of(msg.payload.get(self.key), self.n_partitions)

    def ack(self, n: int) -> None:
        with self.cond:
            for _ in range(min(n, len(self.inflight))):
                self.inflight.popleft()
                self.acked += 1
            self.cond.notify_all()


class _Peer:
    """One connected client: socket, identity, counters, proxy registry,
    negotiated wire parameters, and the outbound coalescing queue."""

    def __init__(self, conn: socket.socket, addr):
        self.conn = conn
        self.addr = addr
        self.name = f"{addr[0]}:{addr[1]}"
        self.send_lock = threading.Lock()
        self.subs: dict[int, _ProxySub] = {}
        self.frames_in = 0
        self.frames_out = 0
        self.bytes_in = 0
        self.bytes_out = 0
        self.raw_bytes_in = 0       # pre-compression serialization, received
        self.raw_bytes_out = 0      # pre-compression serialization, sent
        self.frames_coalesced = 0   # msgs frames carrying >1 message
        self.connected_at = time.monotonic()
        self.last_seen = self.connected_at
        self.dropped = False
        self.drop_lock = threading.Lock()
        # negotiated by hello; a peer that never says hello keeps v1 framing
        self.proto = 1
        self.codec: str | None = None      # None = host default (v1)
        self.max_frame_msgs = 1
        # per-direction trained dictionaries: send_dict compresses our
        # frames (announced to the peer FIRST), recv_dict reads theirs
        self.send_dict: bytes | None = None
        self.recv_dict: bytes | None = None
        self.dict_samples: list[bytes] | None = None  # sampling until train
        self.train_lock = threading.Lock()
        # outbound message queue drained by the sender thread into coalesced
        # frames: (sid, encoded_message) records
        self.outq: deque[tuple[int, bytes]] = deque()
        self.out_cond = threading.Condition()
        self.out_gone = False


class BusServer:
    """Expose a host's :class:`~.bus.MessageBus` subjects over TCP.

    One listener thread accepts connections; each peer gets a reader thread
    (frame dispatch) and one pump thread per remote subscription.  A peer
    whose connection drops — or that stays silent past ``hb_timeout``
    seconds (clients ping every heartbeat interval) — is *reaped*: every
    unacknowledged in-flight message is requeued ahead of its proxy's
    backlog and the proxy departs through the bus's atomic group hand-off,
    re-homing the peer's share to surviving members.

    ``port=0`` binds an OS-assigned port; read :attr:`address` for the
    actual one.  The server is data-plane only — it never registers
    subjects itself; the Operator owning ``bus`` does (see
    :meth:`~.operator.Operator.serve`).
    """

    def __init__(self, bus: MessageBus, host: str = "127.0.0.1",
                 port: int = 0, *, window: int = DEFAULT_WINDOW,
                 hb_timeout: float = 10.0, compress_level: int = 1,
                 max_frame_msgs: int = DEFAULT_MAX_FRAME_MSGS,
                 max_frame_delay_ms: float = 0.0,
                 dict_train_frames: int = DICT_TRAIN_FRAMES):
        self.bus = bus
        self.window = window
        self.hb_timeout = hb_timeout
        self._level = compress_level
        self.max_frame_msgs = max(1, max_frame_msgs)
        self._frame_delay = max(0.0, max_frame_delay_ms) / 1000.0
        self._dict_train_frames = max(0, dict_train_frames)
        self._lock = threading.Lock()
        self._peers: dict[int, _Peer] = {}
        self._peer_ids = itertools.count()
        self._sids = itertools.count()
        self.accepted = 0
        self.reaped = 0          # peers dropped for heartbeat silence
        self.disconnects = 0     # peers gone for any reason
        self._closed = threading.Event()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(32)
        self.address: tuple[str, int] = self._listener.getsockname()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="busserver-accept", daemon=True)
        self._accept_thread.start()
        self._reaper_thread = threading.Thread(
            target=self._reap_loop, name="busserver-reaper", daemon=True)
        self._reaper_thread.start()

    # -- connection plumbing -------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closed.is_set():
            try:
                conn, addr = self._listener.accept()
            except OSError:
                return  # listener closed
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            peer = _Peer(conn, addr)
            pid = next(self._peer_ids)
            with self._lock:
                self._peers[pid] = peer
                self.accepted += 1
            threading.Thread(target=self._serve_peer, args=(pid, peer),
                             name=f"busserver-peer-{pid}", daemon=True).start()
            threading.Thread(target=self._sender_loop, args=(pid, peer),
                             name=f"busserver-send-{pid}", daemon=True).start()

    def _serve_peer(self, pid: int, peer: _Peer) -> None:
        try:
            while not self._closed.is_set():
                frame, nbytes, raw_n = read_frame(
                    peer.conn, dictionary=lambda: peer.recv_dict)
                peer.frames_in += 1
                peer.bytes_in += nbytes
                peer.raw_bytes_in += raw_n
                peer.last_seen = time.monotonic()
                if not self._dispatch(peer, frame):
                    break  # clean bye
        except (ConnectionError, OSError, TransportError,
                msgpack.UnpackException) as e:
            _dbg(f"server: peer {peer.name} read loop ended: {e!r}")
        finally:
            self._drop_peer(pid, peer)

    def _send(self, peer: _Peer, frame: dict, *, plain: bool = False) -> None:
        """Ship one frame with the peer's negotiated codec.  ``plain=True``
        suppresses the trained dictionary — the ``dict`` announcement itself
        must be readable before the receiver has it."""
        data, raw = _encode_frame(frame, level=self._level, codec=peer.codec,
                                  dictionary=None if plain
                                  else peer.send_dict)
        with peer.send_lock:
            peer.conn.sendall(data)
            peer.frames_out += 1
            peer.bytes_out += len(data)
            peer.raw_bytes_out += len(raw)
        if not plain:
            self._maybe_train(peer, raw)

    def _maybe_train(self, peer: _Peer, raw: bytes) -> None:
        """Sample one raw frame; once enough accumulate, train a zstd
        dictionary, ANNOUNCE it (a plain ``dict`` frame, so the receiver
        has the bytes before any ``DXZ2`` frame exists), then switch this
        direction's sends over to it."""
        if peer.dict_samples is None:
            return
        with peer.train_lock:
            samples = peer.dict_samples
            if samples is None:
                return
            samples.append(raw)
            if len(samples) < self._dict_train_frames:
                return
            peer.dict_samples = None  # one-shot per connection
        d = train_dictionary(samples)
        if d is None:
            return  # degenerate samples — keep plain zstd frames
        try:
            self._send(peer, {"op": "dict", "data": d}, plain=True)
        except OSError:
            return  # dying connection; the drop path handles it
        peer.send_dict = d

    def _reply(self, peer: _Peer, rid, **kw) -> None:
        self._send(peer, {"rid": rid, "ok": True, **kw})

    def _reply_error(self, peer: _Peer, rid, exc: Exception) -> None:
        kind = type(exc).__name__
        if kind not in _ERROR_KINDS:
            kind = "BusError"
        self._send(peer, {"rid": rid, "ok": False, "kind": kind,
                          "error": str(exc)})

    # -- frame dispatch ------------------------------------------------------
    def _dispatch(self, peer: _Peer, frame: dict) -> bool:
        """Handle one frame; returns False on a clean ``bye``."""
        op = frame.get("op")
        rid = frame.get("rid")
        if op == "ping":
            self._send(peer, {"op": "pong", "t": frame.get("t")})
            return True
        if op == "ack":
            proxy = peer.subs.get(frame["sid"])
            if proxy is not None:
                proxy.ack(int(frame.get("n", 1)))
            return True
        if op == "dict":
            # the peer trained a dictionary for ITS send direction; every
            # later frame from it may carry the DXZ2 tag
            peer.recv_dict = bytes(frame["data"])
            return True
        if op == "bye":
            return False
        try:
            if op == "hello":
                proto = int(frame.get("proto", 0))
                if proto not in SUPPORTED_PROTOS:
                    raise TransportError(
                        f"protocol version mismatch: server speaks "
                        f"{PROTO_VERSION}, client {frame.get('proto')}")
                if frame.get("peer"):
                    peer.name = str(frame["peer"])
                peer.proto = min(proto, PROTO_VERSION)
                if peer.proto >= 2:
                    # codec: first of OUR preference the client can read.
                    # zlib closes every list (available_codecs), so a
                    # zlib-only peer negotiates down instead of failing.
                    theirs = [str(c) for c in frame.get("codecs") or ["zlib"]]
                    peer.codec = next(
                        (c for c in available_codecs() if c in theirs),
                        "zlib")
                    peer.max_frame_msgs = max(1, min(
                        self.max_frame_msgs,
                        int(frame.get("max_frame_msgs")
                            or DEFAULT_MAX_FRAME_MSGS)))
                    if peer.codec == "zstd" and self._dict_train_frames > 0:
                        peer.dict_samples = []
                # the reply is already compressed with the negotiated codec —
                # safe, because the client advertised it and readers dispatch
                # on the blob tag, not on negotiation state
                self._reply(peer, rid, proto=peer.proto, codec=peer.codec,
                            max_frame_msgs=peer.max_frame_msgs,
                            subjects=self.bus.subjects())
            elif op == "issue_token":
                token = self.bus.issue_token(frame.get("name", peer.name),
                                             frame.get("subjects"))
                self._reply(peer, rid, token=token)
            elif op == "revoke_token":
                self.bus.revoke_token(frame["token"])
                self._reply(peer, rid)
            elif op == "subscribe":
                self._handle_subscribe(peer, rid, frame)
            elif op == "unsubscribe":
                self._retire_proxy(peer, frame["sid"], clean=True)
                self._reply(peer, rid)
            elif op == "publish":
                msg = self.bus.publish(frame["subject"], frame["payload"],
                                       token=frame["token"],
                                       headers=frame.get("headers"))
                self._reply(peer, rid, seq=msg.seq,
                            offset=msg.headers.get("offset"))
            elif op == "pubs":
                # batched publish (v2): sequential, NOT atomic — an error
                # mid-batch leaves the prefix published; the error reply
                # tells the client where it stopped
                seqs: list = []
                offsets: list = []
                try:
                    for payload in frame["payloads"]:
                        msg = self.bus.publish(
                            frame["subject"], payload, token=frame["token"],
                            headers=dict(frame.get("headers") or {}))
                        seqs.append(msg.seq)
                        offsets.append(msg.headers.get("offset"))
                except Exception as e:
                    kind = type(e).__name__
                    if kind not in _ERROR_KINDS:
                        kind = "BusError"
                    self._send(peer, {"rid": rid, "ok": False, "kind": kind,
                                      "error": str(e),
                                      "published": len(seqs)})
                else:
                    self._reply(peer, rid, seqs=seqs, offsets=offsets)
            elif op == "stats":
                self._reply(peer, rid, stats=self.bus.stats())
            elif op == "group_info":
                self._reply(peer, rid, info=self.bus.group_info(
                    frame["subject"], frame["group"]))
            elif op == "durable_info":
                log = self.bus.durable_log(frame["subject"])
                self._reply(peer, rid,
                            info=None if log is None else log.info())
            elif op == "backlog":
                self._reply(peer, rid, backlog=self.bus.backlog(
                    frame["subject"]))
            elif op == "subjects":
                self._reply(peer, rid, subjects=self.bus.subjects())
            elif op == "note_lost":
                self.bus.note_lost(frame["subject"], int(frame.get("n", 1)))
                if rid is not None:
                    self._reply(peer, rid)
            else:
                raise TransportError(f"unknown op {op!r}")
        except Exception as e:  # surface bus errors to the caller, not the log
            if rid is not None:
                self._reply_error(peer, rid, e)
        return True

    def _handle_subscribe(self, peer: _Peer, rid, frame: dict) -> None:
        key = frame.get("key")
        partitions = int(frame.get("partitions") or KEYED_PARTITIONS)
        replay_from = frame.get("replay_from")
        policy = policy_from_legacy(frame.get("group"), key, partitions)
        if policy is not None and frame.get("steal"):
            policy = dataclasses.replace(policy, steal=True)
        sub = self.bus.subscribe(
            frame["subject"], token=frame["token"],
            maxsize=frame.get("maxsize"), wire=False,
            name=frame.get("name") or f"{peer.name}#{frame.get('sid', '?')}",
            policy=policy,
            replay=ReplayFrom(replay_from) if replay_from is not None
            else None)
        sid = int(frame["sid"])
        proxy = _ProxySub(sid, sub, min(self.window,
                                        frame.get("maxsize") or self.window),
                          key, partitions)
        # work stealing reads a victim's in-flight partitions; for a proxy
        # the popped burst is NOT the whole story — messages shipped over
        # the wire stay busy until the peer acks them
        def _wire_inflight(proxy=proxy):
            with proxy.cond:
                return {t for t, _ in proxy.inflight if t is not None}
        sub._external_inflight = _wire_inflight
        peer.subs[sid] = proxy
        proxy.thread = threading.Thread(
            target=self._pump, args=(peer, proxy),
            name=f"busserver-pump-{peer.name}-{sid}", daemon=True)
        proxy.thread.start()
        self._reply(peer, rid, sid=sid)

    # -- the pump: proxy mailbox -> outbound queue, with an acked window -----
    def _pump(self, peer: _Peer, proxy: _ProxySub) -> None:
        sub = proxy.sub
        while not proxy.closed.is_set():
            with proxy.cond:
                while (len(proxy.inflight) >= proxy.window
                       and not proxy.closed.is_set()):
                    proxy.cond.wait(0.25)
                budget = proxy.window - len(proxy.inflight)
            if proxy.closed.is_set():
                return
            msgs = sub.next_batch(max(1, min(budget, 64)), timeout=0.25)
            if not msgs:
                if sub.closed and sub.qsize() == 0:
                    # subject unregistered / bus closed underneath us — tell
                    # the client so its consumer unblocks instead of hanging
                    with contextlib.suppress(OSError):
                        self._send(peer, {"op": "sub_closed",
                                          "sid": proxy.sid})
                    return
                continue
            # in-flight BEFORE enqueue: if the connection dies anywhere
            # between here and the wire, the messages are still tracked and
            # will be requeued by the drop path
            with proxy.cond:
                for m in msgs:
                    proxy.inflight.append((proxy.tag_of(m), m))
            if not self._enqueue_out(
                    peer, [(proxy.sid, encode_message(m)) for m in msgs]):
                return  # peer dropped; inflight requeues via _retire_proxy

    def _enqueue_out(self, peer: _Peer, records: list) -> bool:
        with peer.out_cond:
            if peer.out_gone:
                return False
            peer.outq.extend(records)
            peer.out_cond.notify()
        return True

    # -- the sender: outbound queue -> coalesced frames on the socket --------
    def _sender_loop(self, pid: int, peer: _Peer) -> None:
        """Drain the peer's outbound queue into ``msgs`` frames — up to the
        negotiated ``max_frame_msgs`` records (or :data:`MAX_COALESCED_BYTES`
        of payload) per frame, one length prefix + one compression + one
        syscall for the lot.  This is the wire analog of the fused layer's
        batched bursts: framing overhead amortizes across the batch.  v1
        peers (``max_frame_msgs == 1``) get the classic one-``msg``-per-frame
        stream from the same loop."""
        while True:
            with peer.out_cond:
                while not peer.outq and not peer.out_gone:
                    peer.out_cond.wait(0.25)
                if peer.out_gone:
                    return
                batch: list[tuple[int, bytes]] = []
                size = 0
                while (peer.outq and len(batch) < peer.max_frame_msgs
                       and size < MAX_COALESCED_BYTES):
                    sid, enc = peer.outq.popleft()
                    batch.append((sid, enc))
                    size += len(enc)
            if (self._frame_delay > 0 and len(batch) < peer.max_frame_msgs
                    and size < MAX_COALESCED_BYTES):
                # optional top-up window: trade max_frame_delay_ms of
                # latency for fuller frames on trickling producers
                with peer.out_cond:
                    if not peer.outq and not peer.out_gone:
                        peer.out_cond.wait(self._frame_delay)
                    while (peer.outq and len(batch) < peer.max_frame_msgs
                           and size < MAX_COALESCED_BYTES):
                        sid, enc = peer.outq.popleft()
                        batch.append((sid, enc))
                        size += len(enc)
            try:
                if peer.proto >= 2:
                    if len(batch) > 1:
                        peer.frames_coalesced += 1
                    self._send(peer, {"op": "msgs",
                                      "ms": [[sid, enc]
                                             for sid, enc in batch]})
                else:
                    for sid, enc in batch:
                        self._send(peer, {"op": "msg", "sid": sid, "m": enc})
            except OSError as e:
                _dbg(f"server: sender for {peer.name} failed: {e!r}")
                self._drop_peer(pid, peer)
                return

    def _retire_proxy(self, peer: _Peer, sid: int, *, clean: bool) -> None:
        """Stop a proxy's pump, requeue its unacknowledged messages ahead of
        the backlog, and depart the bus — the single redelivery path for
        clean unsubscribes, clean byes, and crashed peers alike."""
        proxy = peer.subs.pop(sid, None)
        if proxy is None:
            return
        proxy.closed.set()
        with proxy.cond:
            proxy.cond.notify_all()
        if proxy.thread is not None and proxy.thread is not \
                threading.current_thread():
            proxy.thread.join(timeout=2.0)
        with proxy.cond:
            pending = list(proxy.inflight)
            proxy.inflight.clear()
        proxy.sub.requeue_front(pending)
        self.bus.unsubscribe(proxy.sub)

    def _drop_peer(self, pid: int, peer: _Peer) -> None:
        with peer.drop_lock:
            if peer.dropped:
                return
            peer.dropped = True
        with peer.out_cond:
            # stop the sender; whatever it never shipped is still in the
            # proxies' in-flight windows and requeues below
            peer.out_gone = True
            peer.out_cond.notify_all()
        with self._lock:
            self._peers.pop(pid, None)
            self.disconnects += 1
        for sid in list(peer.subs):
            self._retire_proxy(peer, sid, clean=False)
        with contextlib.suppress(OSError):
            peer.conn.close()

    def _reap_loop(self) -> None:
        while not self._closed.wait(min(1.0, self.hb_timeout / 4)):
            now = time.monotonic()
            with self._lock:
                stale = [(pid, p) for pid, p in self._peers.items()
                         if now - p.last_seen > self.hb_timeout]
            for pid, peer in stale:
                self.reaped += 1
                _dbg(f"server: reaping {peer.name} "
                     f"(silent {now - peer.last_seen:.1f}s)")
                with contextlib.suppress(OSError):
                    peer.conn.shutdown(socket.SHUT_RDWR)
                self._drop_peer(pid, peer)

    # -- introspection / lifecycle -------------------------------------------
    def stats(self) -> dict:
        """Federated transport view: per-peer connection state, frame/byte
        counters, subscription + in-flight depth — the server half of the
        ``transport`` metrics surface (see ``docs/metrics.md``)."""
        now = time.monotonic()
        with self._lock:
            peers = list(self._peers.values())
        return {
            "address": list(self.address),
            "peers": {
                p.name: {
                    "addr": f"{p.addr[0]}:{p.addr[1]}",
                    "connected_s": now - p.connected_at,
                    "last_seen_s": now - p.last_seen,
                    "proto": p.proto,
                    "codec": p.codec,
                    "max_frame_msgs": p.max_frame_msgs,
                    "frames_in": p.frames_in,
                    "frames_out": p.frames_out,
                    "frames_coalesced": p.frames_coalesced,
                    "bytes_in": p.bytes_in,
                    "bytes_out": p.bytes_out,
                    "raw_bytes_in": p.raw_bytes_in,
                    "raw_bytes_out": p.raw_bytes_out,
                    "wire_ratio": (round(p.raw_bytes_out / p.bytes_out, 4)
                                   if p.bytes_out else None),
                    "dict": p.send_dict is not None,
                    "subscriptions": len(p.subs),
                    "inflight": sum(len(s.inflight) for s in p.subs.values()),
                }
                for p in peers
            },
            "accepted": self.accepted,
            "reaped": self.reaped,
            "disconnects": self.disconnects,
        }

    def close(self) -> None:
        """Stop accepting, drop every peer (reaping their proxies)."""
        self._closed.set()
        with contextlib.suppress(OSError):
            self._listener.close()
        with self._lock:
            peers = list(self._peers.items())
        for pid, peer in peers:
            with contextlib.suppress(OSError):
                peer.conn.shutdown(socket.SHUT_RDWR)
            self._drop_peer(pid, peer)


# ---------------------------------------------------------------------------
# Client side
# ---------------------------------------------------------------------------

class RemoteSubscription:
    """Client half of a remote subscription — the :class:`~.bus.Subscription`
    surface the sidecar reads, backed by frames from the server's proxy.

    Messages are **acknowledged when popped** (``auto_ack=True``, the
    default — right for the executor's pump, which owns redelivery through
    the reconciler) or explicitly via :meth:`ack` (``auto_ack=False`` —
    consumers that must survive their own crash ack only after recording a
    message's effect, which is what makes redelivery exactly-once
    end-to-end).  Replay state (``replaying`` etc.) lives server-side on the
    proxy; the client-side counters exist for metrics compatibility.
    """

    def __init__(self, bus: "RemoteBus", sid: int, subject: str, name: str,
                 group: str | None, auto_ack: bool):
        self._bus = bus
        self.sid = sid
        self.subject = subject
        self.name = name
        self.group = group
        self.wire = False
        self.auto_ack = auto_ack
        self.received = 0
        self.dropped = 0
        self.closed = False
        self.replayed = 0
        self.deduped = 0
        self.healed = 0
        self._q: deque[Message] = deque()
        self._cond = threading.Condition()

    @property
    def replaying(self) -> bool:
        """Always False client-side: the server proxy drains replay before
        any frame is shipped, so by the time a message arrives here the
        replay→live ordering is already settled."""
        return False

    def replay_lag(self) -> int:
        """Client-side stub (0); use the ``durable_info`` RPC for the log
        view."""
        return 0

    def _deliver(self, msg: Message) -> None:
        with self._cond:
            self._q.append(msg)
            self.received += 1
            self._cond.notify()

    def _close_local(self) -> None:
        with self._cond:
            self.closed = True
            self._cond.notify_all()

    def next(self, timeout: float | None = None) -> Message | None:
        """Blocking pop; None on timeout or close."""
        got = self.next_batch(1, timeout)
        return got[0] if got else None

    def next_batch(self, max_n: int,
                   timeout: float | None = None) -> list[Message]:
        """Pop up to ``max_n`` received messages (blocking up to ``timeout``
        for the first, like :meth:`.bus.Subscription.next_batch`); with
        ``auto_ack`` the pop acknowledges them to the server."""
        if max_n < 1:
            return []
        out: list[Message] = []
        with self._cond:
            if not self._q and not self.closed:
                self._cond.wait(timeout)
            while self._q and len(out) < max_n:
                out.append(self._q.popleft())
        if out and self.auto_ack:
            self._bus._ack(self.sid, len(out))
        return out

    def ack(self, n: int = 1) -> None:
        """Acknowledge ``n`` popped messages (``auto_ack=False`` mode).
        Unacknowledged messages are redelivered to group survivors if this
        client drops."""
        self._bus._ack(self.sid, n)

    def qsize(self) -> int:
        """Messages received but not yet popped."""
        with self._cond:
            return len(self._q)

    def close(self) -> None:
        """Local close; prefer ``RemoteBus.unsubscribe`` for a clean leave."""
        self._close_local()


class _RemoteLogHandle:
    """Client-side handle to a remote subject's durable log: just enough of
    the :class:`~.durable.DurableLog` surface for metrics (``info()``)."""

    def __init__(self, bus: "RemoteBus", subject: str):
        self._bus = bus
        self.subject = subject

    def info(self) -> dict:
        """The remote log's catalog entry (RPC per call)."""
        info = self._bus._rpc("durable_info", subject=self.subject)["info"]
        return info or {}


class RemoteBus:
    """TCP client satisfying the :class:`~.bus.BusLike` seam against a
    remote :class:`BusServer`.

    ``address`` is ``"host:port"`` or a ``(host, port)`` tuple.  The
    constructor connects eagerly, retrying with exponential backoff until
    ``connect_timeout`` elapses — so a worker process can be started before
    its server and still come up.  A heartbeat thread pings every
    ``hb_interval`` seconds; if nothing (pong or data) arrives within
    ``hb_timeout`` the connection is declared dead: pending RPCs fail,
    every subscription closes (consumers unblock — the server reaps the
    member and re-homes its share), and the next RPC attempts a fresh
    connection (counted in ``reconnects``).  By default subscriptions do
    NOT silently re-subscribe across a reconnect: membership is explicit, a
    new subscription is a new ring identity.  ``resubscribe=True`` opts in:
    subscriptions stay open across a drop, the heartbeat thread reconnects
    proactively, and on success the client replays its subscription set —
    each re-join walks the normal ring-join path under the same stable
    ``name`` (live, not replaying; messages in flight during the outage
    were re-homed to survivors or redelivered — at-least-once, exactly like
    any other peer crash).

    The hello handshake negotiates the wire fast path (PROTO_VERSION 2):
    the client advertises its codecs (``codecs=`` narrows them — a
    zlib-only process advertises ``["zlib"]`` and a zstd host negotiates
    down) and its coalescing appetite; both directions then train a
    per-connection zstd dictionary on early frames and announce it with a
    ``dict`` frame before using it.
    """

    def __init__(self, address, *, peer: str = "",
                 connect_timeout: float = 5.0, rpc_timeout: float = 10.0,
                 hb_interval: float = 1.0, hb_timeout: float = 6.0,
                 compress_level: int = 1, resubscribe: bool = False,
                 codecs: list[str] | None = None,
                 max_frame_msgs: int = DEFAULT_MAX_FRAME_MSGS,
                 dict_train_frames: int = DICT_TRAIN_FRAMES):
        if isinstance(address, str):
            host, _, port = address.rpartition(":")
            address = (host or "127.0.0.1", int(port))
        self.address: tuple[str, int] = tuple(address)
        self.peer = peer or f"remote-{id(self):x}"
        self._connect_timeout = connect_timeout
        self._rpc_timeout = rpc_timeout
        self._hb_interval = hb_interval
        self._hb_timeout = hb_timeout
        self._level = compress_level
        self._resubscribe = resubscribe
        self._codecs = list(codecs) if codecs is not None \
            else available_codecs()
        self._max_frame_msgs = max(1, max_frame_msgs)
        self._dict_train_frames = max(0, dict_train_frames)
        self._lock = threading.RLock()       # connection state
        self._conn_lock = threading.RLock()  # serializes (re)connects
        self._send_lock = threading.Lock()
        self._sock: socket.socket | None = None
        self._rids = itertools.count()
        self._sids = itertools.count()
        self._waiters: dict[int, tuple[threading.Event, list]] = {}
        self._subs: dict[int, RemoteSubscription] = {}
        self._sub_meta: dict[int, dict] = {}  # subscribe args, for re-joins
        self._closed = False
        self._last_frame = 0.0
        # negotiated wire state (per connection; reset by _connect)
        self._proto = 1
        self._codec: str | None = "zlib"   # hello is universally readable
        self._send_dict: bytes | None = None
        self._recv_dict: bytes | None = None
        self._dict_samples: list[bytes] | None = None
        # federated metrics (the client half of docs/metrics.md "transport")
        self.frames_in = 0
        self.frames_out = 0
        self.bytes_in = 0
        self.bytes_out = 0
        self.raw_bytes_in = 0
        self.raw_bytes_out = 0
        self.frames_coalesced = 0
        self.reconnects = 0
        self.subjects_cache: list[str] = []
        self._connect(initial=True)
        self._hb_thread = threading.Thread(
            target=self._hb_loop, name=f"remotebus-hb-{self.peer}",
            daemon=True)
        self._hb_thread.start()

    # -- connection management ----------------------------------------------
    def connected(self) -> bool:
        """True while a live socket exists."""
        with self._lock:
            return self._sock is not None and not self._closed

    def _connect(self, *, initial: bool = False) -> None:
        """(Re)establish the connection, with exponential backoff up to
        ``connect_timeout`` total.  Serialized under ``_conn_lock`` so a
        heartbeat-driven reconnect and an RPC-driven one cannot race two
        sockets into place.  On a v2 server the hello negotiates codec and
        coalescing; on success with ``resubscribe`` the kept subscription
        set re-joins."""
        with self._conn_lock:
            if self.connected():
                return  # another thread won the reconnect race
            deadline = time.monotonic() + self._connect_timeout
            backoff = 0.05
            last_err: Exception | None = None
            while time.monotonic() < deadline and not self._closed:
                try:
                    sock = socket.create_connection(
                        self.address,
                        timeout=max(0.2, deadline - time.monotonic()))
                    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                    sock.settimeout(None)
                    with self._lock:
                        self._sock = sock
                        if not initial:
                            self.reconnects += 1
                        self._last_frame = time.monotonic()
                        # per-connection wire state: the hello itself must be
                        # readable by ANY server, so zlib until negotiated
                        self._proto = 1
                        self._codec = "zlib"
                        self._send_dict = None
                        self._recv_dict = None
                        self._dict_samples = None
                    threading.Thread(target=self._read_loop, args=(sock,),
                                     name=f"remotebus-read-{self.peer}",
                                     daemon=True).start()
                    hello = self._rpc("hello", peer=self.peer,
                                      proto=PROTO_VERSION,
                                      codecs=self._codecs,
                                      max_frame_msgs=self._max_frame_msgs)
                    self.subjects_cache = list(hello.get("subjects", []))
                    with self._lock:
                        self._proto = int(hello.get("proto", 1))
                        # a v1 server names no codec: stay on zlib, which
                        # every reader dispatches by tag anyway
                        self._codec = str(hello.get("codec") or "zlib")
                        if (self._codec == "zstd"
                                and self._dict_train_frames > 0):
                            self._dict_samples = []
                    if not initial and self._resubscribe:
                        self._restore_subscriptions()
                    return
                except (OSError, TransportError) as e:
                    last_err = e
                    with self._lock:
                        self._sock = None
                    time.sleep(backoff)
                    backoff = min(backoff * 2, 1.0)
            raise TransportError(
                f"could not connect to bus server at "
                f"{self.address[0]}:{self.address[1]} within "
                f"{self._connect_timeout}s: {last_err}")

    def _restore_subscriptions(self) -> None:
        """Re-issue every kept subscription after a reconnect (the
        ``resubscribe=True`` path) — each re-join is an ordinary ring join
        under the same stable name.  The server may not have reaped our old
        proxy yet (keyed groups refuse duplicate ring names), so a refused
        join retries until roughly ``rpc_timeout``; a subscription that
        still cannot re-join closes locally rather than lying about
        membership."""
        with self._lock:
            metas = [(sid, dict(meta)) for sid, meta in self._sub_meta.items()
                     if sid in self._subs]
        for sid, meta in sorted(metas):
            deadline = time.monotonic() + self._rpc_timeout
            while True:
                try:
                    self._rpc("subscribe", _noconnect=True, sid=sid, **meta)
                    break
                except TransportError:
                    # connection died again mid-restore — the next
                    # reconnect restarts the whole restore
                    return
                except BusError as e:
                    if time.monotonic() >= deadline:
                        _dbg(f"client {self.peer}: resubscribe sid={sid} "
                             f"failed: {e!r}")
                        with self._lock:
                            sub = self._subs.pop(sid, None)
                            self._sub_meta.pop(sid, None)
                        if sub is not None:
                            sub._close_local()
                        break
                    time.sleep(0.1)

    def _drop_connection(self, reason: str) -> None:
        _dbg(f"client {self.peer}: dropping connection: {reason}")
        with self._lock:
            sock, self._sock = self._sock, None
            waiters = list(self._waiters.values())
            self._waiters.clear()
            if self._resubscribe and not self._closed:
                # keep subscriptions open across the outage: consumers stay
                # blocked on their local queues and resume after the
                # reconnect re-joins them
                subs = []
            else:
                subs = list(self._subs.values())
                self._subs.clear()
                self._sub_meta.clear()
        if sock is not None:
            # shutdown() before close(): the reader thread still holds the
            # fd, so a bare close() would neither send FIN to the server nor
            # unblock the local recv — the peer would linger until reaped
            with contextlib.suppress(OSError):
                sock.shutdown(socket.SHUT_RDWR)
            with contextlib.suppress(OSError):
                sock.close()
        for event, slot in waiters:
            slot.append(TransportError(f"connection lost: {reason}"))
            event.set()
        for sub in subs:
            sub._close_local()

    def _read_loop(self, sock: socket.socket) -> None:
        try:
            while True:
                frame, nbytes, raw_n = read_frame(
                    sock, dictionary=lambda: self._recv_dict)
                with self._lock:
                    if self._sock is not sock:
                        return  # superseded by a reconnect
                    self.frames_in += 1
                    self.bytes_in += nbytes
                    self.raw_bytes_in += raw_n
                    self._last_frame = time.monotonic()
                self._handle_frame(frame)
        except (ConnectionError, OSError, TransportError,
                msgpack.UnpackException) as e:
            with self._lock:
                current = self._sock is sock
            if current:
                self._drop_connection(repr(e))

    def _handle_frame(self, frame: dict) -> None:
        rid = frame.get("rid")
        if rid is not None:
            with self._lock:
                waiter = self._waiters.pop(rid, None)
            if waiter is not None:
                event, slot = waiter
                slot.append(frame)
                event.set()
            return
        op = frame.get("op")
        if op == "msg":
            sub = self._subs.get(frame["sid"])
            if sub is not None:
                sub._deliver(decode_message(frame["m"]))
            else:
                # arrived after a local unsubscribe raced the pump — the
                # server redelivers it when the unsubscribe lands
                pass
        elif op == "msgs":
            # coalesced delivery frame (v2): many (sid, message) records
            records = frame.get("ms") or []
            if len(records) > 1:
                with self._lock:
                    self.frames_coalesced += 1
            for sid, enc in records:
                sub = self._subs.get(sid)
                if sub is not None:
                    sub._deliver(decode_message(enc))
        elif op == "dict":
            # the server trained a dictionary for ITS send direction
            self._recv_dict = bytes(frame["data"])
        elif op == "sub_closed":
            sub = self._subs.pop(frame["sid"], None)
            if sub is not None:
                self._sub_meta.pop(frame["sid"], None)
                sub._close_local()
        # pongs need no handling beyond the last_frame stamp above

    def _hb_loop(self) -> None:
        while not self._closed:
            time.sleep(self._hb_interval)
            if self._closed:
                return
            with self._lock:
                sock = self._sock
                stale = (sock is not None and
                         time.monotonic() - self._last_frame
                         > self._hb_timeout)
            if sock is None:
                if self._resubscribe and not self._closed:
                    # proactive reconnect: with kept subscriptions there may
                    # be no RPC traffic to trigger one, so the heartbeat
                    # thread owns re-establishing the link
                    try:
                        self._connect()
                    except TransportError:
                        pass  # backoff exhausted — retry next heartbeat
                continue
            if stale:
                self._drop_connection("heartbeat timeout")
                continue
            try:
                self._send_frame({"op": "ping", "t": time.monotonic()})
            except TransportError:
                pass  # _send_frame already dropped the connection

    # -- frame / rpc plumbing -------------------------------------------------
    def _send_frame(self, frame: dict, *, plain: bool = False) -> None:
        with self._lock:
            sock = self._sock
            codec = self._codec
            send_dict = None if plain else self._send_dict
        if sock is None:
            raise TransportError("not connected")
        data, raw = _encode_frame(frame, level=self._level, codec=codec,
                                  dictionary=send_dict)
        try:
            with self._send_lock:
                sock.sendall(data)
        except OSError as e:
            self._drop_connection(repr(e))
            raise TransportError(f"send failed: {e}") from None
        with self._lock:
            self.frames_out += 1
            self.bytes_out += len(data)
            self.raw_bytes_out += len(raw)
        if not plain:
            self._maybe_train(raw)

    def _maybe_train(self, raw: bytes) -> None:
        """Sample raw (pre-compression) frame bytes for this client's send
        direction; at the threshold, train, announce the dictionary in a
        plain frame, and only THEN start using it — ``_send_lock``
        serializes the wire, so no ``DXZ2`` frame can precede its
        announcement."""
        with self._lock:
            samples = self._dict_samples
            if samples is None:
                return
            samples.append(raw)
            if len(samples) < self._dict_train_frames:
                return
            self._dict_samples = None  # one-shot: train exactly once
        d = train_dictionary(samples)
        if d is None:
            return  # degenerate sample set — keep sending plain blobs
        try:
            self._send_frame({"op": "dict", "data": d}, plain=True)
        except TransportError:
            return  # connection died; next connection retrains from scratch
        with self._lock:
            self._send_dict = d

    def _rpc(self, op: str, *, _timeout: float | None = None,
             _noconnect: bool = False, **kw) -> dict:
        """Send a request frame and wait for its correlated reply; maps
        server-side bus errors back to their exception types.  Attempts one
        reconnect (with backoff) when the connection is down, unless
        ``_noconnect`` (used inside the restore path, where a nested
        reconnect would re-enter the restore and double-subscribe)."""
        if self._closed:
            raise TransportError("RemoteBus is closed")
        if not self.connected() and op != "hello":
            if _noconnect:
                raise TransportError("not connected")
            self._connect()
        rid = next(self._rids)
        event, slot = threading.Event(), []
        with self._lock:
            self._waiters[rid] = (event, slot)
        try:
            self._send_frame({"op": op, "rid": rid, **kw})
        except TransportError:
            with self._lock:
                self._waiters.pop(rid, None)
            raise
        if not event.wait(_timeout or self._rpc_timeout):
            with self._lock:
                self._waiters.pop(rid, None)
            raise TransportError(f"rpc {op!r} timed out")
        reply = slot[0]
        if isinstance(reply, Exception):
            raise reply
        if not reply.get("ok", False):
            exc = _ERROR_KINDS.get(reply.get("kind", ""), BusError)
            raise exc(reply.get("error", "remote error"))
        return reply

    def _ack(self, sid: int, n: int) -> None:
        try:
            self._send_frame({"op": "ack", "sid": sid, "n": n})
        except TransportError:
            pass  # the server redelivers unacked messages to survivors

    # -- the BusLike surface ---------------------------------------------------
    def issue_token(self, name: str,
                    subjects: Iterable[str] | None = None) -> str:
        """Mint a token on the remote bus (None = allowed everywhere)."""
        return self._rpc("issue_token", name=name,
                         subjects=None if subjects is None
                         else list(subjects))["token"]

    def revoke_token(self, token: str) -> None:
        """Invalidate a remote token (best-effort when disconnected)."""
        with contextlib.suppress(TransportError):
            self._rpc("revoke_token", token=token)

    def subscribe(self, subject: str, *, token: str,
                  maxsize: int | None = None, wire: bool = False,
                  name: str = "", policy: DeliveryPolicy | None = None,
                  replay: ReplayFrom | None = None,
                  group: str | None = None,
                  key: str | None = None,
                  partitions: int | None = None,
                  replay_from=None, auto_ack: bool = True
                  ) -> RemoteSubscription:
        """Join the remote subject — as a first-class queue-group or
        keyed-ring member under a :class:`~.delivery.Group` /
        :class:`~.delivery.Keyed` ``policy`` (``name`` is the ring identity;
        pick a stable one for keyed recovery).  The deprecated
        ``group=``/``key=``/``partitions=``/``replay_from=`` kwargs map onto
        ``policy``/``replay`` with a warning, exactly as on
        :meth:`MessageBus.subscribe`.  ``wire`` is accepted for signature
        compatibility and ignored: everything here crosses the wire by
        construction.  ``auto_ack=False`` defers acknowledgement to
        :meth:`RemoteSubscription.ack` for exactly-once consumers."""
        steal = bool(getattr(policy, "steal", False))
        group, key, partitions = resolve_policy(policy, group, key,
                                                partitions)
        replay_from = resolve_replay(replay, replay_from)
        del wire  # every remote delivery is wire-encoded already
        sid = next(self._sids)
        sub = RemoteSubscription(self, sid, subject,
                                 name or f"{self.peer}#{sid}", group,
                                 auto_ack)
        with self._lock:
            self._subs[sid] = sub
        try:
            self._rpc("subscribe", sid=sid, subject=subject, token=token,
                      maxsize=maxsize, name=sub.name, group=group, key=key,
                      partitions=partitions, replay_from=replay_from,
                      steal=steal)
        except Exception:
            with self._lock:
                self._subs.pop(sid, None)
            raise
        with self._lock:
            # the re-join after a reconnect is always LIVE (replay_from=None):
            # the server requeues whatever our dead proxy held, and a keyed
            # replay would double-deliver everything the old proxy acked
            self._sub_meta[sid] = dict(
                subject=subject, token=token, maxsize=maxsize,
                name=sub.name, group=group, key=key, partitions=partitions,
                replay_from=None, steal=steal)
        return sub

    def unsubscribe(self, sub: RemoteSubscription) -> None:
        """Clean leave: the server requeues anything unacknowledged and
        departs the proxy (group backlog re-homes to survivors)."""
        with self._lock:
            self._subs.pop(sub.sid, None)
            self._sub_meta.pop(sub.sid, None)
        try:
            self._rpc("unsubscribe", sid=sub.sid)
        except TransportError:
            pass  # connection already gone — the server reaped the proxy
        sub._close_local()

    def publish(self, subject: str, payload: dict, *, token: str,
                headers: dict | None = None) -> Message:
        """Publish through the server's bus (authz + schema validation and
        durable append happen there); returns the delivered message's
        envelope with its remote ``seq`` (and ``offset`` when durable)."""
        reply = self._rpc("publish", subject=subject, payload=payload,
                          token=token, headers=headers)
        hdrs = dict(headers or {})
        if reply.get("offset") is not None:
            hdrs["offset"] = reply["offset"]
        return Message(subject=subject, payload=payload, seq=reply["seq"],
                       headers=hdrs)

    def publish_many(self, subject: str, payloads, *, token: str,
                     headers: dict | None = None) -> list[Message]:
        """Publish a batch through ONE ``pubs`` round trip (v2 servers) —
        the batched twin of :meth:`publish`, amortizing the per-RPC wire
        overhead the same way coalesced delivery frames do.  The batch is
        sequential, not atomic: on an error mid-batch the already-published
        prefix stays published (the raised error carries no partial result;
        use distinct payload markers if you need to probe).  Against a v1
        server this degrades to per-message :meth:`publish` calls."""
        payloads = list(payloads)
        if not payloads:
            return []
        with self._lock:
            proto = self._proto
        if proto < 2:
            return [self.publish(subject, p, token=token, headers=headers)
                    for p in payloads]
        reply = self._rpc("pubs", subject=subject, payloads=payloads,
                          token=token, headers=headers)
        seqs = reply.get("seqs") or []
        offsets = reply.get("offsets") or [None] * len(seqs)
        out: list[Message] = []
        for payload, seq, off in zip(payloads, seqs, offsets):
            hdrs = dict(headers or {})
            if off is not None:
                hdrs["offset"] = off
            out.append(Message(subject=subject, payload=payload, seq=seq,
                               headers=hdrs))
        return out

    def note_lost(self, subject: str, n: int = 1) -> None:
        """Forward poison-message loss accounting to the remote subject."""
        with contextlib.suppress(TransportError):
            self._send_frame({"op": "note_lost", "subject": subject, "n": n})

    def group_info(self, subject: str, group: str) -> dict | None:
        """Snapshot of a remote queue group (RPC)."""
        return self._rpc("group_info", subject=subject, group=group)["info"]

    def durable_log(self, subject: str):
        """A metrics handle to the remote subject's durable log, or None
        for fire-and-forget subjects."""
        info = self._rpc("durable_info", subject=subject)["info"]
        return None if info is None else _RemoteLogHandle(self, subject)

    def stats(self) -> dict:
        """The remote bus's full per-subject stats (RPC)."""
        return self._rpc("stats")["stats"]

    def backlog(self, subject: str) -> int:
        """Deepest consumer lag on the remote subject (RPC)."""
        return self._rpc("backlog", subject=subject)["backlog"]

    def subjects(self) -> list[str]:
        """Registered subjects on the remote bus (RPC; also cached from the
        handshake in ``subjects_cache``)."""
        subjects = self._rpc("subjects")["subjects"]
        self.subjects_cache = list(subjects)
        return subjects

    # -- federated metrics -----------------------------------------------------
    def transport_stats(self) -> dict:
        """Client-side connection state + frame counters; the sidecar
        surfaces this under its ``transport`` metric (docs/metrics.md)."""
        with self._lock:
            return {
                "peer": f"{self.address[0]}:{self.address[1]}",
                "connected": self._sock is not None and not self._closed,
                "proto": self._proto,
                "codec": self._codec,
                "frames_in": self.frames_in,
                "frames_out": self.frames_out,
                "frames_coalesced": self.frames_coalesced,
                "bytes_in": self.bytes_in,
                "bytes_out": self.bytes_out,
                "raw_bytes_in": self.raw_bytes_in,
                "raw_bytes_out": self.raw_bytes_out,
                "wire_ratio": (round(self.raw_bytes_out / self.bytes_out, 4)
                               if self.bytes_out else None),
                "dict": self._send_dict is not None,
                "reconnects": self.reconnects,
                "resubscribe": self._resubscribe,
                "subscriptions": len(self._subs),
            }

    def close(self) -> None:
        """Clean shutdown: unsubscribe everything, say bye, drop the
        socket."""
        if self._closed:
            return
        for sub in list(self._subs.values()):
            self.unsubscribe(sub)
        with contextlib.suppress(TransportError):
            self._send_frame({"op": "bye"})
        self._closed = True
        self._drop_connection("closed")


__all__ = [
    "PROTO_VERSION", "SUPPORTED_PROTOS", "MAX_FRAME_BYTES",
    "DEFAULT_WINDOW", "DEFAULT_MAX_FRAME_MSGS", "MAX_COALESCED_BYTES",
    "DICT_TRAIN_FRAMES",
    "BusServer", "RemoteBus", "RemoteSubscription", "TransportError",
    "pack_frame", "read_frame", "unpack_frame",
]
