"""DataX Sidecar — per-instance data-plane manager + metrics (paper §4).

"The main role of the DataX Sidecar is to automatically manage data
communication (it manages the connection, subscriptions, and publishing to the
messages bus).  Also, DataX Sidecar monitors the health of the user's
application; it exposes ... metrics such as the systems resources utilization
and the number of messages received, dropped, and published."

One Sidecar is attached to every running instance.  It owns the bus
subscriptions and the publish path (business logic never touches the bus), and
keeps the counters that drive (a) autoscaling, (b) straggler detection, and
(c) the health checks the reconciler uses to restart dead instances.
"""
from __future__ import annotations

import threading
import time
from typing import Sequence

from .bus import BusLike, MessageBus, Subscription
from .delivery import DeliveryPolicy, ReplayFrom, policy_from_legacy
from .schema import Message


class Sidecar:
    """Connection + subscription + publish manager, with metrics.

    ``bus`` is any :class:`~.bus.BusLike` — the in-process bus or a
    :class:`~.transport.RemoteBus`; in the remote case the sidecar's
    :meth:`metrics` additionally carries the federated ``transport`` block
    (connection state, frames/bytes in/out, reconnects)."""

    def __init__(self, instance_id: str, bus: MessageBus | BusLike, *,
                 inputs: Sequence[str] = (), output: str | None = None,
                 token: str | None = None, queue_size: int = 256,
                 wire: bool = False, policy: DeliveryPolicy | None = None,
                 group: str | None = None,
                 key: str | None = None, replay_from=None):
        self.instance_id = instance_id
        self._bus = bus
        self._output = output
        # the sidecar is runtime fabric, not user surface: it carries the
        # (group, key) pair the Operator derived from the StreamSpec, or an
        # explicit typed policy, and always speaks the typed form to the bus
        policy = policy if policy is not None \
            else policy_from_legacy(group, key)
        self.policy = policy
        legacy = policy.legacy_args() if policy is not None \
            else (None, None, None)
        self.group, self.key = legacy[0], legacy[1]
        if isinstance(replay_from, ReplayFrom):
            replay_from = replay_from.start
        self.replay_from = replay_from
        self._token = token or bus.issue_token(
            instance_id, list(inputs) + ([output] if output else []))
        # policy: scaled instances of one entity join the same queue group
        # (Group) on every input subject — each message reaches exactly one
        # of them (a worker pool); Keyed upgrades the group so each key
        # sticks to one member; None keeps broadcast replicas.
        # replay_from starts each subscription on the (durable) subject's
        # log — the pump then serves history before live messages.
        self._subs: list[Subscription] = [
            bus.subscribe(s, token=self._token, maxsize=queue_size, wire=wire,
                          name=f"{instance_id}:{s}", policy=policy,
                          replay=ReplayFrom(replay_from)
                          if replay_from is not None else None)
            for s in inputs
        ]
        self._rr = 0  # round-robin cursor over input subscriptions
        self._lock = threading.Lock()
        # deploy-time datax-check findings for this instance's stream
        # (operator pushes them at spawn via note_diagnostics)
        self.diagnostics: list[dict] = []
        # metrics
        self.published = 0
        self.processed = 0
        self.errors = 0
        self.latency_ewma_s = 0.0     # business-logic processing latency
        self.warmup_s = 0.0           # one-off setup (jit compile) cost
        self.batches = 0              # next_batch() bursts handed out
        self.batch_msgs = 0           # messages delivered inside those bursts
        self.max_batch_seen = 0       # deepest single burst
        self.started_at = time.monotonic()
        self.last_activity = self.started_at
        self._ewma_alpha = 0.2
        # counters owned by the business logic (e.g. a fused device unit's
        # device_fallbacks) — attached by the Executor, read by metrics()
        self._process_stats: dict | None = None

    # -- data plane (used by the SDK / runtime, not by business logic) -------
    def _pull(self, max_n: int, timeout: float | None
              ) -> tuple[str, list] | None:
        """The round-robin scan shared by :meth:`next` and
        :meth:`next_batch`: a fast non-blocking pass over every input, then
        a blocking wait on the round-robin head.  Returns
        ``(subject, [messages])`` (1 <= len <= max_n) or None."""
        if not self._subs or max_n < 1:
            return None
        n = len(self._subs)
        for i in range(n):
            sub = self._subs[(self._rr + i) % n]
            msgs = sub.next_batch(max_n, timeout=0)
            if msgs:
                self._rr = (self._rr + i + 1) % n
                self.last_activity = time.monotonic()
                return (sub.subject, msgs)
        if timeout == 0:
            return None
        sub = self._subs[self._rr % n]
        msgs = sub.next_batch(max_n, timeout=timeout)
        if not msgs:
            return None
        self._rr = (self._rr + 1) % n
        self.last_activity = time.monotonic()
        return (sub.subject, msgs)

    def next(self, timeout: float | None = 0.1) -> tuple[str, Message] | None:
        """Round-robin poll across input subscriptions.

        Returns (stream_name, message) or None if nothing arrived in time.
        Mirrors the paper's SDK ``next()`` returning "the name of the stream
        and the message".
        """
        got = self._pull(1, timeout)
        return None if got is None else (got[0], got[1][0])

    def next_batch(self, max_n: int, timeout: float | None = 0.1
                   ) -> tuple[str, list[Message]] | None:
        """Round-robin burst pull: up to ``max_n`` messages from ONE input
        subscription in a single drain (``(stream_name, [messages])``).

        Blocking behaviour mirrors :meth:`next`, and a shallow mailbox
        yields a 1-message burst with unchanged latency.  Burst sizes are
        recorded (``batches`` / ``batch_msgs`` / ``max_batch_seen``) so the
        metrics surface shows how well batched execution is amortizing.
        """
        got = self._pull(max_n, timeout)
        if got is not None:
            self._note_batch(len(got[1]))
        return got

    def _note_batch(self, size: int) -> None:
        with self._lock:
            self.batches += 1
            self.batch_msgs += size
            if size > self.max_batch_seen:
                self.max_batch_seen = size
            self.last_activity = time.monotonic()

    def emit(self, payload: dict, headers: dict | None = None) -> None:
        if self._output is None:
            raise RuntimeError(f"instance {self.instance_id} has no output stream")
        self._bus.publish(self._output, payload, token=self._token,
                          headers=headers)
        with self._lock:
            self.published += 1
            self.last_activity = time.monotonic()

    # -- bookkeeping ----------------------------------------------------------
    def record_processing(self, latency_s: float, ok: bool = True) -> None:
        with self._lock:
            self.processed += 1
            if not ok:
                self.errors += 1
            a = self._ewma_alpha
            self.latency_ewma_s = (1 - a) * self.latency_ewma_s + a * latency_s

    def attach_process_stats(self, stats: dict | None) -> None:
        """Adopt a mutable counter dict owned by the business logic (a fused
        device unit exposes ``process.stats``) so logic-level counters —
        ``device_fallbacks`` above all — reach the REST metrics surface."""
        self._process_stats = stats

    def note_lost(self, subject: str, n: int = 1) -> None:
        """Report in-flight message destruction (poison message crashing the
        instance) to the bus, where it lands on the subject's ``lost`` stat."""
        self._bus.note_lost(subject, n)

    def record_warmup(self, seconds: float) -> None:
        """One-off setup cost (e.g. jit compile of a fused device chain) —
        surfaced as its own metric, excluded from the latency EWMA so the
        reconciler never mistakes compilation for straggling."""
        with self._lock:
            self.warmup_s = seconds
            self.last_activity = time.monotonic()

    # -- the REST-analog metrics endpoint (paper: sidecar exposes REST API) ---
    def _group_metrics(self) -> dict:
        """Per-input queue-group view: delivery lag (delivered vs drained —
        i.e. handed to the pool but not yet popped), reroutes, and for keyed
        groups the live partition assignment map + per-partition backlog.
        This is how group/partition state reaches the REST surface instead
        of living only in ``bus.stats()``."""
        out = {}
        for s in self._subs:
            snap = self._bus.group_info(s.subject, self.group)
            if snap is None:
                continue
            info = {
                "policy": snap["policy"],
                "members": len(snap["members"]),
                "delivered": snap["delivered"],
                "lag": snap["backlog"],       # delivered - drained
                "rerouted": snap["rerouted"],
                # work stealing: moves an idle member pulled from the
                # deepest mailbox, and denials (deep victim, nothing
                # eligible).  Sustained stealing marks a straggler — the
                # autoscaler reads these through the same snapshot.
                "steal_enabled": snap.get("steal_enabled", False),
                "stolen": snap.get("stolen", 0),
                "steal_denied": snap.get("steal_denied", 0),
            }
            if snap["policy"] == "keyed":
                info.update(key=snap["key"],
                            assignment=snap["assignment"],
                            partition_backlog=snap["partition_backlog"],
                            stolen_partitions=snap.get(
                                "stolen_partitions", {}))
            out[s.subject] = info
        return out

    def _durable_metrics(self) -> dict:
        """Per-subject durable-log catalog for every durable input/output
        (depth, segments, retention evictions, offsets) — the REST surface
        for the durability layer."""
        out = {}
        subjects = [s.subject for s in self._subs]
        if self._output is not None:
            subjects.append(self._output)
        for subject in subjects:
            log = self._bus.durable_log(subject)
            if log is not None and subject not in out:
                out[subject] = log.info()
        return out

    def _transport_metrics(self) -> dict | None:
        """Client-side wire counters when the bus is remote (None when the
        bus is in-process): per-peer connection state, frames/bytes in/out,
        and reconnect count — the federated half of docs/metrics.md's
        transport section."""
        stats = getattr(self._bus, "transport_stats", None)
        return stats() if callable(stats) else None

    def metrics(self) -> dict:
        received = sum(s.received for s in self._subs)
        dropped = sum(s.dropped for s in self._subs)
        backlog = sum(s.qsize() for s in self._subs)
        groups = self._group_metrics() if self.group else {}
        durable = self._durable_metrics()
        replaying = any(s.replaying for s in self._subs)
        replayed = sum(s.replayed for s in self._subs)
        replay_lag = max((s.replay_lag() for s in self._subs), default=0)
        deduped = sum(s.deduped for s in self._subs)
        with self._lock:
            stats = self._process_stats or {}
            return {
                "instance": self.instance_id,
                "group": self.group,
                "key": self.key,
                "received": received,
                "dropped": dropped,
                "published": self.published,
                "processed": self.processed,
                "errors": self.errors,
                "backlog": backlog,
                "groups": groups,
                "latency_ewma_s": self.latency_ewma_s,
                "warmup_s": self.warmup_s,
                "batches": self.batches,
                "batch_msgs": self.batch_msgs,
                "max_batch_seen": self.max_batch_seen,
                "avg_batch": (self.batch_msgs / self.batches
                              if self.batches else 0.0),
                # logic-owned counters (fused units): batched_bursts > 0 is
                # the signal that vmapped device batching actually engaged —
                # the sidecar-level batches/batch_msgs above count every
                # mailbox pull, including per-message degrades
                "device_fallbacks": int(stats.get("device_fallbacks", 0)),
                "unstackable_bursts": int(stats.get("unstackable_bursts", 0)),
                "batched_bursts": int(stats.get("batched_bursts", 0)),
                "batched_msgs": int(stats.get("batched_msgs", 0)),
                # mesh execution surface (fused units on a multi-device
                # mesh): how many devices the unit's mesh spans (1 = no
                # mesh), how many bursts ran SPMD-partitioned across it,
                # how many device buffers were reused across a linked
                # exit/entry pair instead of re-uploading from host, and
                # the autotuned burst ceiling currently in force
                "mesh_devices": int(stats.get("mesh_devices", 1)),
                "sharded_bursts": int(stats.get("sharded_bursts", 0)),
                "resident_links": int(stats.get("resident_links", 0)),
                "max_batch_current": int(stats.get("max_batch_current", 0)),
                # durability surface: log catalogs per durable subject,
                # replay progress of this instance's subscriptions, and the
                # age of the newest exactly-once recovery snapshot (logic-
                # owned — keyed stateful stages stamp last_snapshot_ts)
                "durable": durable,
                "replaying": replaying,
                "replayed": replayed,
                "replay_lag": replay_lag,
                "deduped": deduped,
                "snapshots": int(stats.get("snapshots", 0)),
                "snapshot_age_s": (
                    time.time() - stats["last_snapshot_ts"]
                    if stats.get("last_snapshot_ts") else None),
                # federated transport view (remote buses only, else None)
                "transport": self._transport_metrics(),
                # deploy-time datax-check findings anchored at this
                # instance's stream (code + severity; full records on
                # Operator.diagnostics())
                "diagnostics": [{"code": d.get("code"),
                                 "severity": d.get("severity")}
                                for d in self.diagnostics],
                "uptime_s": time.monotonic() - self.started_at,
                "idle_s": time.monotonic() - self.last_activity,
            }

    def note_diagnostics(self, entries) -> None:
        """Attach deploy-time ``datax check`` findings (JSON dicts) for
        this instance's stream; surfaced in :meth:`metrics`."""
        self.diagnostics = [dict(e) for e in entries]

    def healthy(self, stall_timeout_s: float = 60.0) -> bool:
        m = self.metrics()
        if m["errors"] > 0 and m["processed"] == m["errors"]:
            return False  # every message errored
        return True

    def close(self) -> None:
        for s in self._subs:
            self._bus.unsubscribe(s)
        self._bus.revoke_token(self._token)
