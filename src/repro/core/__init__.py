"""repro.core — the DataX platform: the paper's primary contribution in JAX.

Entities (§2), Operator coherence + lifecycle (§4), message bus (NATS analog),
sidecar metrics, serverless autoscaling, platform state, and the 3-method SDK.
"""
from .analyze import (Diagnostic, DiagnosticsError, Severity,
                      analyze_application, analyze_target)
from .app import Application, AppValidationError
from .bus import (KEYED_PARTITIONS, BusError, BusLike, KeyedGroup, MessageBus,
                  QueueGroup, Subscription, Unauthorized, UnknownSubject,
                  decode_message, decode_payload, encode_message,
                  encode_payload, drain, partition_of, partition_owner,
                  ring_assignment, stable_hash)
from .compression import CompressionError, codec_name, train_dictionary
from .delivery import (Broadcast, DeliveryPolicy, Group, Keyed, Listen, Peer,
                       ReplayFrom)
from .dsl import App, DSLError, GadgetHandle, SchemaMismatch, StreamHandle, connect
from .durable import (SNAPSHOT_TABLE, DurableError, DurableLog, Retention,
                      iter_log, resolve_replay_from, schema_fingerprint)
from .entities import (ActuatorSpec, AnalyticsUnitSpec, DatabaseSpec,
                       DriverSpec, EntityKind, GadgetSpec, Placement,
                       SensorSpec, StreamSpec)
from .fusion import (BarrierReason, FusedStage, ResidentArray,
                     fuse_application, fusion_mesh, mesh_axis_names,
                     plan_segments)
from .operator import CoherenceError, Operator, OperatorError
from .schema import (KNOWN_MESH_AXES, ConfigSchema, FieldSpec, Message,
                     ShardSpec, StreamSchema)
from .sdk import BatchInterrupted, DataX, LogicContext, sdk_entrypoint
from .serverless import (AutoScaler, Executor, InstanceHandle, RemoteWorker,
                         ScalePolicy)
from .sidecar import Sidecar
from .state import Database, KeyedStore, StateError, StateStore, Table
from .transport import (BusServer, RemoteBus, RemoteSubscription,
                        TransportError)

__all__ = [
    "App", "DSLError", "GadgetHandle", "SchemaMismatch", "StreamHandle",
    "connect",
    "Application", "AppValidationError",
    "Diagnostic", "DiagnosticsError", "Severity", "analyze_application",
    "analyze_target",
    "CompressionError", "codec_name", "train_dictionary",
    "SNAPSHOT_TABLE", "DurableError", "DurableLog", "Retention",
    "iter_log", "resolve_replay_from", "schema_fingerprint",
    "Broadcast", "DeliveryPolicy", "Group", "Keyed", "Listen", "Peer",
    "ReplayFrom",
    "KEYED_PARTITIONS", "BusError", "BusLike", "KeyedGroup", "MessageBus",
    "QueueGroup", "Subscription", "Unauthorized", "UnknownSubject",
    "decode_message", "decode_payload", "encode_message", "encode_payload",
    "drain", "partition_of", "partition_owner", "ring_assignment",
    "stable_hash",
    "ActuatorSpec", "AnalyticsUnitSpec", "DatabaseSpec", "DriverSpec",
    "EntityKind", "GadgetSpec", "Placement", "SensorSpec", "StreamSpec",
    "BarrierReason", "FusedStage", "ResidentArray", "fuse_application",
    "fusion_mesh", "mesh_axis_names", "plan_segments",
    "CoherenceError", "Operator", "OperatorError",
    "KNOWN_MESH_AXES", "ConfigSchema", "FieldSpec", "Message", "ShardSpec",
    "StreamSchema",
    "BatchInterrupted", "DataX", "LogicContext", "sdk_entrypoint",
    "AutoScaler", "Executor", "InstanceHandle", "RemoteWorker", "ScalePolicy",
    "Sidecar",
    "Database", "KeyedStore", "StateError", "StateStore", "Table",
    "BusServer", "RemoteBus", "RemoteSubscription", "TransportError",
]
