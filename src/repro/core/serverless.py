"""Serverless execution + autoscaling (paper §3 "Serverless stream processing").

The Executor is the platform's compute fabric: it runs every driver / AU /
actuator instance on worker threads, wrapped in a Sidecar.  Developers never
touch it — the Operator asks for instances and the Executor provides them,
which is the paper's serverless claim ("developers only provide the business
logic and actual execution is handled transparently").

The AutoScaler turns sidecar metrics into scale decisions — the paper: "these
metrics also drive the auto-scaling process".
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import threading
import time
import traceback
from typing import Any, Callable, Mapping, Sequence

from .bus import BusLike, MessageBus
from .delivery import DeliveryPolicy
from .sdk import BatchInterrupted, DataX, LogicContext, is_sdk_style
from .sidecar import Sidecar
from .state import Database


@dataclasses.dataclass
class InstanceHandle:
    """One running instance of a driver/AU/actuator."""

    instance_id: str
    entity_kind: str                 # driver | analytics_unit | actuator
    entity_name: str                 # code-entity name
    owner: str                       # sensor/stream/gadget that requested it
    config: dict
    sidecar: Sidecar
    thread: threading.Thread
    stop_event: threading.Event
    node: str | None = None          # simulated placement (paper's USB affinity)
    started_at: float = dataclasses.field(default_factory=time.monotonic)
    crashed: bool = False
    completed: bool = False          # ran to normal end (e.g. finite driver)
    crash_info: str = ""

    def alive(self) -> bool:
        return self.thread.is_alive()

    def stop(self, join_timeout: float = 2.0) -> None:
        self.stop_event.set()
        self.thread.join(timeout=join_timeout)
        self.sidecar.close()


class Executor:
    """Thread-backed serverless fabric.

    ``bus`` is anything satisfying the :class:`~.bus.BusLike` seam — the
    in-process :class:`~.bus.MessageBus` or a :class:`~.transport.RemoteBus`
    speaking TCP to another host's bus: instances run identically either
    way, which is what makes :class:`RemoteWorker` a two-line wrapper."""

    def __init__(self, bus: MessageBus | BusLike):
        self._bus = bus
        self._instances: dict[str, InstanceHandle] = {}
        self._lock = threading.RLock()
        self._ids = itertools.count()

    # ------------------------------------------------------------------ run
    def start_instance(self, *, entity_kind: str, entity_name: str, owner: str,
                       logic: Callable, config: dict,
                       inputs: Sequence[str] = (), output: str | None = None,
                       db: Database | None = None, node: str | None = None,
                       queue_size: int = 256,
                       policy: DeliveryPolicy | None = None,
                       group: str | None = None,
                       key: str | None = None,
                       max_batch: int | None = None,
                       replay_from=None) -> InstanceHandle:
        """``policy`` (a typed :class:`~.delivery.DeliveryPolicy`) selects
        how this instance's input subscriptions share each subject:
        ``Group(name)`` joins the named bus queue group — all instances
        started under the same group form a single-delivery worker pool
        (scaling adds capacity, not copies); ``Keyed(group, field)``
        upgrades the pool so the named payload field is hashed and every
        message for a key reaches the same member (stateful workers scale
        without splitting a key's state).  The bare ``group=``/``key=``
        kwargs are the same thing spelled positionally and stay accepted
        here (this is runtime fabric, not the deprecated subscribe surface).
        ``max_batch`` bounds the mailbox burst handed to a batching-capable
        process (one exposing ``process_batch``) per pull; None defers to the
        process's own ``default_max_batch`` (1 = per-message pulls), which
        fused device units may autotune upward under sustained backlog.
        ``replay_from`` (durable inputs only) starts the input subscriptions
        on the subjects' logs — history is served before live delivery."""
        iid = f"{owner}/{entity_name}-{next(self._ids):04d}"
        stop_event = threading.Event()
        sidecar = Sidecar(iid, self._bus, inputs=inputs, output=output,
                          queue_size=queue_size, policy=policy, group=group,
                          key=key, replay_from=replay_from)

        handle = InstanceHandle(
            instance_id=iid, entity_kind=entity_kind, entity_name=entity_name,
            owner=owner, config=dict(config), sidecar=sidecar,
            thread=None, stop_event=stop_event, node=node)  # type: ignore[arg-type]

        runner = self._make_runner(handle, logic, db, max_batch)
        thread = threading.Thread(target=runner, name=iid, daemon=True)
        handle.thread = thread
        with self._lock:
            self._instances[iid] = handle
        thread.start()
        return handle

    def _make_runner(self, handle: InstanceHandle, logic: Callable,
                     db: Database | None,
                     max_batch: int | None = None) -> Callable[[], None]:
        sidecar, stop_event = handle.sidecar, handle.stop_event

        def run() -> None:
            try:
                if is_sdk_style(logic):
                    dx = DataX(sidecar, handle.config, db=db, stop_event=stop_event)
                    logic(dx)
                    return
                ctx = LogicContext(handle.config, db=db,
                                   instance_id=handle.instance_id,
                                   stop_event=stop_event)
                made = logic(ctx)
                if handle.entity_kind == "driver":
                    self._drive_source(made, sidecar, stop_event)
                else:
                    self._pump(made, sidecar, stop_event,
                               sink=handle.entity_kind == "actuator",
                               max_batch=max_batch)
            except Exception:
                handle.crashed = True
                handle.crash_info = traceback.format_exc()
            else:
                handle.completed = True

        return run

    @staticmethod
    def _drive_source(made: Any, sidecar: Sidecar,
                      stop_event: threading.Event) -> None:
        """Drivers: iterate a generator (or poll a callable) and emit."""
        if callable(made) and not hasattr(made, "__next__"):
            # callable driver: poll until it returns None or stop
            while not stop_event.is_set():
                t0 = time.monotonic()
                payload = made()
                if payload is None:
                    return
                sidecar.emit(payload)
                sidecar.record_processing(time.monotonic() - t0)
            return
        for payload in made:
            if stop_event.is_set():
                return
            if payload is None:
                continue
            sidecar.emit(payload)
            sidecar.record_processing(0.0)

    @staticmethod
    def _pump(process: Callable, sidecar: Sidecar, stop_event: threading.Event,
              sink: bool, max_batch: int | None = None) -> None:
        """AUs/actuators: pull → business logic → (emit).

        A process exposing ``process_batch(stream, [payloads]) ->
        [out | None, ...]`` (fused device units) switches the pump to
        drain-a-burst mode: each pull takes everything queued up to
        ``max_batch`` (the ``.scaled(max_batch=)`` knob, falling back to the
        process's own ``default_max_batch``) and hands the whole burst to one
        batched call.  A shallow mailbox yields 1-message bursts routed
        through the plain per-message path, so idle latency is unchanged —
        batching only engages when there is a backlog to amortize.
        """
        if not callable(process):
            raise TypeError("AU/actuator factory must return a callable process fn")
        warm = getattr(process, "warmup", None)
        if warm is not None and not stop_event.is_set():
            # fused device units expose .warmup to jit-compile ahead of the
            # first real message; best-effort (a failure just means the first
            # message pays the compile or falls back to the host chain), and
            # recorded separately so compile time never skews the latency
            # EWMA that drives straggler replacement
            t0 = time.monotonic()
            with contextlib.suppress(Exception):
                warm()
            sidecar.record_warmup(time.monotonic() - t0)
        sidecar.attach_process_stats(getattr(process, "stats", None))
        batch_fn = getattr(process, "process_batch", None)
        # a process marked ``wants_headers`` receives the message headers —
        # the durable-log offset rides there, which is how exactly-once
        # keyed stages pair each update with its log position
        wants_headers = bool(getattr(process, "wants_headers", False))
        if max_batch is None:
            max_batch = int(getattr(process, "default_max_batch", 1) or 1)
        burst = max(1, max_batch) if batch_fn is not None else 1
        # a process may autotune its own ceiling upward under sustained
        # backlog (fused device units expose current_max_batch); re-read it
        # per pull so deeper bursts engage without restarting the instance.
        # An explicit .scaled(max_batch=) stays authoritative: the process
        # only exposes the hook when the stream declared no ceiling.
        tuned = getattr(process, "current_max_batch", None) \
            if batch_fn is not None else None
        def emit_outs(outs) -> None:
            if sink:
                return
            for out in outs:
                if out is None:
                    continue
                for payload in (out if isinstance(out, list) else [out]):
                    sidecar.emit(payload)

        def account(t0: float, total: int, done: int) -> None:
            dt = (time.monotonic() - t0) / total
            for i in range(total):
                sidecar.record_processing(dt, ok=i < done)

        while not stop_event.is_set():
            if tuned is not None:
                try:
                    burst = max(1, int(tuned()))
                except Exception:
                    tuned = None
            if burst > 1:
                got = sidecar.next_batch(burst, timeout=0.1)
            else:
                one = sidecar.next(timeout=0.1)
                got = None if one is None else (one[0], [one[1]])
            if got is None:
                continue
            stream, msgs = got
            t0 = time.monotonic()
            try:
                if len(msgs) == 1:
                    if wants_headers:
                        outs = [process(stream, msgs[0].payload,
                                        headers=msgs[0].headers)]
                    else:
                        outs = [process(stream, msgs[0].payload)]
                else:
                    outs = batch_fn(stream, [m.payload for m in msgs])
            except BatchInterrupted as bi:
                # a poison message partway through a burst: the successful
                # prefix still flows downstream; only the poison and the
                # never-processed tail die with this instance — and they are
                # accounted, not silently vanished (the reconciler restarts
                # the instance; a group survivor inherits the rest of the
                # mailbox)
                sidecar.note_lost(stream, len(msgs) - len(bi.results))
                account(t0, len(msgs), len(bi.results))
                emit_outs(bi.results)
                raise
            except Exception:
                # poison message: the in-flight messages die with this
                # instance and, under single delivery, the popped copies were
                # the ONLY ones — account them on the subject's lost stat
                sidecar.note_lost(stream, len(msgs))
                account(t0, len(msgs), 0)
                raise
            account(t0, len(msgs), len(msgs))
            emit_outs(outs)

    # ------------------------------------------------------------- lifecycle
    def stop_instance(self, instance_id: str) -> None:
        with self._lock:
            handle = self._instances.pop(instance_id, None)
        if handle is not None:
            handle.stop()

    def instances_of(self, owner: str) -> list[InstanceHandle]:
        with self._lock:
            return [h for h in self._instances.values() if h.owner == owner]

    def all_instances(self) -> list[InstanceHandle]:
        with self._lock:
            return list(self._instances.values())

    def get(self, instance_id: str) -> InstanceHandle | None:
        with self._lock:
            return self._instances.get(instance_id)

    def reap_dead(self) -> list[InstanceHandle]:
        """Remove finished/crashed instances; return them (reconciler restarts)."""
        with self._lock:
            dead = [h for h in self._instances.values()
                    if not h.thread.is_alive()]
            for h in dead:
                del self._instances[h.instance_id]
        for h in dead:
            h.sidecar.close()
        return dead

    def shutdown(self) -> None:
        with self._lock:
            handles = list(self._instances.values())
            self._instances.clear()
        for h in handles:
            h.stop()


# ---------------------------------------------------------------------------
# Cross-host workers
# ---------------------------------------------------------------------------

class RemoteWorker:
    """A worker process's attachment to a remote deployment: one
    :class:`~.transport.RemoteBus` connection plus a local :class:`Executor`
    running instances against it.

    The host process calls :meth:`~.operator.Operator.serve`; a worker
    process then does::

        worker = RemoteWorker("127.0.0.1:47000", peer="gpu-box-1")
        worker.start_instance(entity_kind="analytics_unit", ...,
                              inputs=("readings",), output="scores",
                              group="scores", key="sensor_id")

    and its instances join the host's queue groups / keyed rings as
    first-class members — the rendezvous ring hashes their stable
    subscription names, so cross-host partition hand-off and crashed-worker
    backlog re-homing behave exactly as in-process.  ``start_instance``
    takes the same kwargs as :meth:`Executor.start_instance`.
    """

    def __init__(self, address, *, peer: str = "", connect_timeout: float = 5.0,
                 **remote_kwargs):
        from .transport import RemoteBus
        self.bus = RemoteBus(address, peer=peer,
                             connect_timeout=connect_timeout, **remote_kwargs)
        self.executor = Executor(self.bus)

    def start_instance(self, **kwargs) -> InstanceHandle:
        """Run one instance locally, subscribed/publishing over the wire
        (same signature as :meth:`Executor.start_instance`)."""
        return self.executor.start_instance(**kwargs)

    def all_instances(self) -> list[InstanceHandle]:
        """Handles of every instance this worker is running."""
        return self.executor.all_instances()

    def metrics(self) -> dict:
        """Per-instance sidecar metrics, each carrying the federated
        ``transport`` block (connection state, frames, reconnects)."""
        return {h.instance_id: h.sidecar.metrics()
                for h in self.executor.all_instances()}

    def transport_stats(self) -> dict:
        """This worker's client-side connection counters."""
        return self.bus.transport_stats()

    def close(self) -> None:
        """Stop every instance (their unsubscribes re-home backlog to
        surviving members on the host), then drop the connection."""
        self.executor.shutdown()
        self.bus.close()


# ---------------------------------------------------------------------------
# Autoscaling policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScalePolicy:
    """Backlog/latency-driven scaling thresholds."""

    backlog_high: int = 32        # scale up if backlog-per-instance exceeds this
    backlog_low: int = 2          # scale down if total backlog below this
    idle_s: float = 5.0           # and instances have been idle this long
    cooldown_s: float = 1.0       # min seconds between decisions per stream
    steal_streak: int = 3         # consecutive stealing decisions = straggler


class AutoScaler:
    """Decides instance counts from sidecar metrics (paper §4: metrics drive
    the auto-scaling process).

    Signals are **group-aggregate**: under queue-group (single) delivery the
    pool shares one logical queue split across member mailboxes, so a single
    replica's mailbox depth no longer reflects load — the scale-up test is the
    pool's TOTAL backlog against ``backlog_high × members`` (for broadcast
    replicas every mailbox holds the same messages, so the aggregate form is
    conservative-equivalent at N=1 and stricter above).  Nonzero mailbox drops
    since the last decision are a hard scale-up signal regardless of backlog:
    drops mean the pool is already losing data, not merely lagging.

    Keyed pools add a **per-partition** signal: hashing concentrates hot keys
    on single members, so the aggregate can look healthy while one partition
    (and therefore one member) is drowning.  The sidecar metrics carry the
    keyed groups' exact per-partition backlogs; any partition above
    ``backlog_high`` scales the pool up — more members re-spread the
    remaining partitions off the hot member (a single key can never split,
    but its neighbours can move away).

    Stealing pools add a **straggler** signal: work stealing masks a slow
    member's backlog (idle peers drain it), so the backlog signals above can
    look healthy while the pool quietly burns capacity compensating.  The
    groups' ``stolen`` counter still rising across ``steal_streak``
    consecutive decisions means the imbalance is structural, not a blip —
    scale up by one so the pool stops depending on theft to keep up.
    """

    def __init__(self, policy: ScalePolicy | None = None):
        self.policy = policy or ScalePolicy()
        self._last_decision: dict[str, float] = {}
        # per-instance drop watermarks: a replaced instance must not lower
        # the pool total and mask fresh drops on the survivors
        self._last_drops: dict[str, dict[str, int]] = {}
        # stolen-counter watermark + consecutive-rising streak per stream
        self._last_stolen: dict[str, int] = {}
        self._steal_streak: dict[str, int] = {}

    @staticmethod
    def _stolen_total(metrics: Sequence[Mapping]) -> int:
        """Pool-wide stolen-message/partition count across all groups.
        The counter lives on the group (every member's sidecar reports the
        same value), so take the max per group view, not the sum."""
        total = 0
        seen: dict[str, int] = {}
        for m in metrics:
            for subject, snap in (m.get("groups") or {}).items():
                seen[subject] = max(seen.get(subject, 0),
                                    int(snap.get("stolen", 0)))
        for v in seen.values():
            total += v
        return total

    @staticmethod
    def _hot_partition_backlog(metrics: Sequence[Mapping]) -> int:
        """Deepest per-partition backlog across the pool's keyed groups
        (0 when the pool is not keyed)."""
        worst = 0
        for m in metrics:
            if not m.get("key"):
                continue
            for snap in (m.get("groups") or {}).values():
                pb = snap.get("partition_backlog") or {}
                if pb:
                    worst = max(worst, max(pb.values()))
        return worst

    def decide(self, owner: str, handles: Sequence[InstanceHandle],
               min_instances: int, max_instances: int) -> int:
        """Return the desired instance count for ``owner``."""
        now = time.monotonic()
        cur = len(handles)
        if cur == 0:
            return max(min_instances, 1)
        last = self._last_decision.get(owner, 0.0)
        if now - last < self.policy.cooldown_s:
            return cur
        metrics = [h.sidecar.metrics() for h in handles]
        total_backlog = sum(m["backlog"] for m in metrics)
        hot_partition = self._hot_partition_backlog(metrics)
        prev_drops = self._last_drops.get(owner, {})
        drops = {m["instance"]: m["dropped"] for m in metrics}
        new_drops = any(d > prev_drops.get(iid, 0) for iid, d in drops.items())
        self._last_drops[owner] = drops
        all_idle = all(m["idle_s"] > self.policy.idle_s for m in metrics)
        stolen = self._stolen_total(metrics)
        if stolen > self._last_stolen.get(owner, 0):
            self._steal_streak[owner] = self._steal_streak.get(owner, 0) + 1
        else:
            self._steal_streak[owner] = 0
        self._last_stolen[owner] = stolen
        stealing_hard = (self._steal_streak.get(owner, 0)
                         >= self.policy.steal_streak)

        desired = cur
        if (total_backlog > self.policy.backlog_high * cur or new_drops
                or hot_partition > self.policy.backlog_high) \
                and cur < max_instances:
            desired = min(max_instances, cur * 2)
        elif stealing_hard and cur < max_instances:
            # sustained stealing = a structural straggler; one extra member
            # (not a doubling — the pool is keeping up, just inefficiently)
            desired = cur + 1
            self._steal_streak[owner] = 0
        elif total_backlog <= self.policy.backlog_low and all_idle \
                and cur > min_instances:
            desired = cur - 1
        if desired != cur:
            self._last_decision[owner] = now
        return desired
