"""Stream schemas and messages — the typed payloads that flow on DataX streams.

The paper (§2) defines a stream as "a continuous flow of homogeneous discrete
messages".  Homogeneity is enforced here: every stream carries a
:class:`StreamSchema`, and the bus/operator refuse publishes that do not
conform.  Schemas double as the *compatibility* objects the DataX Operator
checks during upgrades (§4: "new configuration schemas are compatible with the
schemas of the running instances").

Two kinds of fields exist:

* host fields — python scalars/strings/bytes/numpy arrays, carried on the
  message bus (serialized with msgpack at process boundaries);
* device fields — described by ``jax.ShapeDtypeStruct``; these are the stream
  edges that lower onto the TPU mesh (pjit shardings are chosen by the
  operator from these schemas — the paper's "automated data communication").
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Any, Callable, Mapping

import numpy as np

try:  # jax is always present in this repo, but keep the core importable alone
    import jax
    _HAS_JAX = True
except Exception:  # pragma: no cover
    _HAS_JAX = False


# ---------------------------------------------------------------------------
# Field and schema definitions
# ---------------------------------------------------------------------------

#: Permitted scalar type names in host field schemas.
SCALAR_TYPES = ("int", "float", "str", "bool", "bytes")

#: Mesh-axis vocabulary of the platform (launch.mesh / distributed.sharding):
#: ``pod``/``data`` are the data-parallel axes, ``model`` is tensor
#: parallelism.  :meth:`ShardSpec.validate_axes` checks hints against this
#: set (plus whatever axes a live mesh actually has) at ``App.build()``.
KNOWN_MESH_AXES = ("pod", "data", "model")


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Validated per-dimension sharding hint for one device field.

    ``axes`` names one mesh axis (or None = replicate) per array dimension,
    e.g. ``ShardSpec(("data", None))`` for a ``(B, D)`` field whose leading
    dim splits over the data-parallel axis.  This is the typed successor of
    the bare ``sharding=("data", None)`` tuples recorded since the fusion
    pass landed — bare tuples still coerce (with a deprecation note), but
    only a ShardSpec is checked against the mesh-axis vocabulary at
    ``App.build()`` and consumed by the mesh-sharded fused executor.
    """

    axes: tuple
    #: True when this spec was coerced from a legacy bare tuple spelling
    #: (``sharding=("data", None)``); the ``datax check`` DX402 hygiene rule
    #: flags such call sites statically.  Excluded from equality/repr so
    #: coerced specs still compare equal to explicit ones.
    legacy: bool = dataclasses.field(default=False, compare=False, repr=False)

    def __post_init__(self) -> None:
        axes = tuple(self.axes)
        object.__setattr__(self, "axes", axes)
        for a in axes:
            if a is not None and not isinstance(a, str):
                raise ValueError(
                    f"ShardSpec axes must be mesh-axis names or None, "
                    f"got {a!r}")

    def __iter__(self):
        """Iterate per-dimension axis names (None = replicate)."""
        return iter(self.axes)

    def __len__(self) -> int:
        """Number of dimensions the hint covers."""
        return len(self.axes)

    def validate_axes(self, allowed, *, where: str = "") -> None:
        """Raise ValueError if any named axis is outside ``allowed``."""
        unknown = sorted({a for a in self.axes
                          if a is not None and a not in allowed})
        if unknown:
            raise ValueError(
                f"{where + ': ' if where else ''}unknown mesh axes "
                f"{unknown} in sharding hint {self.axes!r}; known axes: "
                f"{sorted(allowed)}")


def _coerce_sharding(value) -> "ShardSpec | None":
    """Normalize a sharding hint: ShardSpec passes through, bare tuples
    coerce with a deprecation note, None stays None."""
    if value is None or isinstance(value, ShardSpec):
        return value
    if isinstance(value, (tuple, list)):
        warnings.warn(
            "bare sharding tuples are deprecated; pass "
            f"sharding=ShardSpec({tuple(value)!r})",
            DeprecationWarning, stacklevel=4)
        return ShardSpec(tuple(value), legacy=True)
    raise ValueError(f"sharding must be a ShardSpec (or legacy tuple), "
                     f"got {type(value).__name__}")


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    """One field of a stream message.

    ``kind`` is one of:
      * a scalar type name from :data:`SCALAR_TYPES`
      * ``"ndarray"`` — a numpy array with optional shape/dtype constraints
      * ``"device"``  — a jax array described by shape/dtype (ShapeDtypeStruct)
      * ``"any"``     — unconstrained (escape hatch, discouraged)
    """

    kind: str
    shape: tuple | None = None  # None = unconstrained; -1 entries = wildcard dims
    dtype: str | None = None
    required: bool = True
    default: Any = None
    #: Sharding hint for device fields: a :class:`ShardSpec` naming one mesh
    #: axis (or None) per dim, e.g. ShardSpec(("data", None)).  A *hint*, not
    #: a constraint — `accepts` ignores it; the fusion pass forwards it so
    #: fused programs are partitioned when a multi-device mesh is available.
    #: Bare tuples still coerce here with a deprecation note.
    sharding: "ShardSpec | None" = None

    def __post_init__(self) -> None:
        allowed = SCALAR_TYPES + ("ndarray", "device", "any")
        if self.kind not in allowed:
            raise ValueError(f"unknown field kind {self.kind!r}; allowed: {allowed}")
        object.__setattr__(self, "sharding", _coerce_sharding(self.sharding))

    # -- validation ---------------------------------------------------------
    def validate(self, value: Any) -> None:
        if self.kind == "any":
            return
        if self.kind in SCALAR_TYPES:
            pytype = {"int": int, "float": (int, float), "str": str,
                      "bool": bool, "bytes": bytes}[self.kind]
            if not isinstance(value, pytype):
                raise TypeError(f"expected {self.kind}, got {type(value).__name__}")
            return
        # array-like kinds
        if self.kind == "ndarray":
            if not isinstance(value, np.ndarray):
                raise TypeError(f"expected ndarray, got {type(value).__name__}")
            self._check_shape_dtype(value.shape, str(value.dtype))
        elif self.kind == "device":
            shape = getattr(value, "shape", None)
            dtype = getattr(value, "dtype", None)
            if shape is None or dtype is None:
                raise TypeError(f"expected array-like with shape/dtype, got {type(value).__name__}")
            self._check_shape_dtype(tuple(shape), str(dtype))

    def _check_shape_dtype(self, shape: tuple, dtype: str) -> None:
        if self.shape is not None:
            if len(shape) != len(self.shape):
                raise TypeError(f"rank mismatch: expected {self.shape}, got {shape}")
            for want, got in zip(self.shape, shape):
                if want != -1 and want != got:
                    raise TypeError(f"shape mismatch: expected {self.shape}, got {shape}")
        if self.dtype is not None and self.dtype != dtype:
            raise TypeError(f"dtype mismatch: expected {self.dtype}, got {dtype}")

    # -- compatibility ------------------------------------------------------
    def accepts(self, other: "FieldSpec") -> bool:
        """True if every value valid under ``other`` is valid under ``self``."""
        if self.kind == "any":
            return True
        if self.kind != other.kind:
            return False
        if self.shape is not None:
            if other.shape is None or len(self.shape) != len(other.shape):
                return False
            if any(want != -1 and want != got
                   for want, got in zip(self.shape, other.shape)):
                return False
        if self.dtype is not None and self.dtype != other.dtype:
            return False
        return True

    def to_shape_dtype_struct(self):
        """Device fields become jax.ShapeDtypeStruct stand-ins (dry-run inputs)."""
        if self.kind != "device":
            raise ValueError(f"field kind {self.kind!r} has no device representation")
        if self.shape is None or self.dtype is None or any(d == -1 for d in self.shape):
            raise ValueError("device fields need fully-concrete shape/dtype")
        if not _HAS_JAX:  # pragma: no cover
            raise RuntimeError("jax unavailable")
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


@dataclasses.dataclass(frozen=True)
class StreamSchema:
    """The homogeneous message type of one stream."""

    fields: Mapping[str, FieldSpec]

    @staticmethod
    def of(**fields: FieldSpec) -> "StreamSchema":
        return StreamSchema(fields=dict(fields))

    @staticmethod
    def device(**arrays: tuple) -> "StreamSchema":
        """Shorthand: StreamSchema.device(tokens=((B, S), 'int32')).

        An optional third tuple element is the sharding hint — a
        :class:`ShardSpec` or its axes tuple:
        ``StreamSchema.device(x=((B, D), 'float32', ShardSpec(('data', None))))``.
        """
        fields = {}
        for k, spec in arrays.items():
            shape, dtype = spec[0], spec[1]
            sharding = spec[2] if len(spec) > 2 and spec[2] else None
            if sharding is not None and not isinstance(sharding, ShardSpec):
                # the shorthand's tuple position is unambiguous — no note
                sharding = ShardSpec(tuple(sharding))
            fields[k] = FieldSpec(kind="device", shape=tuple(shape),
                                  dtype=dtype, sharding=sharding)
        return StreamSchema(fields=fields)

    @staticmethod
    def untyped() -> "StreamSchema":
        return StreamSchema(fields={})  # empty = accept anything

    def validate(self, payload: Mapping[str, Any]) -> None:
        if not self.fields:
            return
        for name, spec in self.fields.items():
            if name not in payload:
                if spec.required and spec.default is None:
                    raise KeyError(f"missing required field {name!r}")
                continue
            try:
                spec.validate(payload[name])
            except TypeError as e:
                raise TypeError(f"field {name!r}: {e}") from None

    def accepts(self, other: "StreamSchema") -> bool:
        """Compatibility: can a consumer expecting ``self`` read ``other``?"""
        if not self.fields:
            return True
        if not other.fields:
            return False  # producer makes no guarantees
        for name, spec in self.fields.items():
            if not spec.required:
                continue
            if name not in other.fields:
                return False
            if not spec.accepts(other.fields[name]):
                return False
        return True

    def device_specs(self) -> dict:
        """ShapeDtypeStructs for all device fields (dry-run stand-ins)."""
        return {k: f.to_shape_dtype_struct()
                for k, f in self.fields.items() if f.kind == "device"}

    def sharding_hints(self) -> dict:
        """Per-field :class:`ShardSpec` hints for device fields (None =
        replicate everywhere)."""
        return {k: f.sharding for k, f in self.fields.items()
                if f.kind == "device"}

    def zero_payload(self) -> dict | None:
        """An all-zeros concrete payload matching this schema, or None.

        Only available when every field is a device field with fully-concrete
        shape/dtype — used by fused device units to trigger jit compilation
        *before* the first real message arrives (warmup)."""
        if not self.fields:
            return None
        out = {}
        for name, f in self.fields.items():
            if f.kind != "device" or f.shape is None or f.dtype is None \
                    or any(d == -1 for d in f.shape):
                return None
            out[name] = np.zeros(f.shape, dtype=f.dtype)
        return out


# ---------------------------------------------------------------------------
# Config schemas (for drivers / AUs / actuators) — §4 upgrade coherency
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConfigSchema:
    """Schema for entity configuration (the paper's "configuration schema").

    ``fields`` maps name -> (type-name, default-or-REQUIRED).  An upgrade is
    *compatible* iff every config valid under the old schema is valid under the
    new one: the new schema may add fields with defaults, may drop fields, may
    relax required->optional, but may not add required fields or change types.
    """

    REQUIRED = "__required__"
    fields: Mapping[str, tuple]  # name -> (type_name, default)

    @staticmethod
    def of(**fields: Any) -> "ConfigSchema":
        """ConfigSchema.of(rate=("float", 1.0), url=("str", ConfigSchema.REQUIRED))"""
        out = {}
        for name, spec in fields.items():
            if isinstance(spec, tuple) and len(spec) == 2:
                out[name] = spec
            else:
                raise ValueError(f"field {name!r}: expected (type, default) tuple")
        return ConfigSchema(fields=out)

    @staticmethod
    def empty() -> "ConfigSchema":
        return ConfigSchema(fields={})

    def validate(self, config: Mapping[str, Any]) -> dict:
        """Validate + apply defaults; returns the resolved config dict."""
        resolved = {}
        pytypes = {"int": int, "float": (int, float), "str": str,
                   "bool": bool, "bytes": bytes, "dict": dict, "list": list,
                   "any": object}
        for name, (tname, default) in self.fields.items():
            if name in config:
                val = config[name]
                want = pytypes.get(tname, object)
                if not isinstance(val, want):
                    raise TypeError(
                        f"config field {name!r}: expected {tname}, got {type(val).__name__}")
                resolved[name] = val
            elif default is ConfigSchema.REQUIRED:
                raise KeyError(f"missing required config field {name!r}")
            else:
                resolved[name] = default
        unknown = set(config) - set(self.fields)
        if unknown:
            raise KeyError(f"unknown config fields: {sorted(unknown)}")
        return resolved

    def accepts_configs_of(self, old: "ConfigSchema") -> bool:
        """True if any config valid under ``old`` validates under ``self``."""
        for name, (tname, default) in self.fields.items():
            if default is not ConfigSchema.REQUIRED:
                continue
            # new required field: old configs must have been required to carry it
            if name not in old.fields:
                return False
            old_t, old_default = old.fields[name]
            if old_default is not ConfigSchema.REQUIRED:
                return False  # old configs may omit it
            if old_t != tname:
                return False
        # type changes on shared fields break compatibility
        return all(name not in old.fields or old.fields[name][0] == tname
                   for name, (tname, _) in self.fields.items())


# ---------------------------------------------------------------------------
# Messages
# ---------------------------------------------------------------------------

_seq_counter = iter(range(1, 1 << 62))


@dataclasses.dataclass
class Message:
    """One discrete message on a stream (paper §2)."""

    subject: str
    payload: dict
    seq: int = dataclasses.field(default_factory=lambda: next(_seq_counter))
    ts: float = dataclasses.field(default_factory=time.monotonic)
    headers: dict = dataclasses.field(default_factory=dict)

    def with_subject(self, subject: str) -> "Message":
        return dataclasses.replace(self, subject=subject)


#: Signature of AU business logic at the host level: payload(s) in, payload out.
HostLogic = Callable[..., Any]
